#!/usr/bin/env python
"""Run a seeded fault-injection campaign against every decode consumer.

Exercises the robustness invariant (docs/robustness.md): every injected
corruption -- archive bit-flips, truncations, torn checkpoint manifests,
mangled in-memory ``Compressed`` fields, lost KV blocks, transient IO
errors -- must be *detected* (a named error), *recovered* (policy salvage
with the degradation reported), *contained* (bounded garbage, right
shape, no crash), or provably inert (*bit_exact*).  Silent wrong data,
hangs, and unnamed exceptions fail the run.

Usage:
  PYTHONPATH=src python tools/faultinject.py --seed 0 --cases 200
  PYTHONPATH=src python tools/faultinject.py --cases 24 --backend pallas -v

Exit status 0 iff zero violations.  CI runs the 200-case seed-0 campaign
on every PR (.github/workflows/ci.yml, job ``fault-injection``).
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="seeded corruption campaign over store / decode / "
                    "checkpoint / KV-paging consumers")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cases", type=int, default=200)
    ap.add_argument("--backend", default="ref",
                    help="decode backend under test (ref, pallas, ...)")
    ap.add_argument("--dir", default=None,
                    help="corpus directory (default: fresh temp dir)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-case watchdog seconds; exceeding it is a "
                         "'hang' violation")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every case as it completes")
    args = ap.parse_args(argv)

    from repro.testing import run_campaign

    t0 = time.time()

    def progress(i, r):
        if args.verbose or not r.ok:
            mark = "ok " if r.ok else "XXX"
            print(f"[{mark}] case {i:4d} {r.case.consumer}/{r.case.kind} "
                  f"seed={r.case.seed} -> {r.outcome}: {r.note}",
                  flush=True)

    report = run_campaign(seed=args.seed, cases=args.cases,
                          base_dir=args.dir, backend=args.backend,
                          timeout=args.timeout, progress=progress)
    print(report.summary())
    print(f"elapsed {time.time() - t0:.1f}s "
          f"(seed {args.seed}, backend {args.backend})")
    if report.violations:
        print(f"FAIL: {len(report.violations)} invariant violation(s):")
        for r in report.violations:
            print(f"  {r.case.consumer}/{r.case.kind} seed={r.case.seed}: "
                  f"{r.outcome}: {r.note}")
        return 1
    print("OK: every injected fault was detected, recovered, contained, "
          "or inert")
    return 0


if __name__ == "__main__":
    sys.exit(main())
