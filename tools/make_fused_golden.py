#!/usr/bin/env python
"""Regenerate the fused-decode golden fixture.

    PYTHONPATH=src python tools/make_fused_golden.py

Rewrites ``tests/golden/fused_nd_golden.json``: for each spec, the field is
generated deterministically (``tests/test_fused_nd.py:_field``), compressed,
and pinned by two digests -- the compressed payload bytes and the two-pass
reconstruction bytes (which the fused path must match bit-for-bit;
asserted here and in ``TestGoldenVectors``).

Only rerun this when an INTENTIONAL format or codec change invalidates the
fixture; commit the diff together with the change that caused it.
"""

from __future__ import annotations

import hashlib
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.join(ROOT, "tests"))

import numpy as np  # noqa: E402

from repro.core.api import Codec  # noqa: E402
from test_fused_nd import (GOLDEN_PATH, _compressed_digest,  # noqa: E402
                           _golden_case)

SPECS = [
    {"shape": [56, 72], "dtype": "f32", "seed": 101, "eb": 1e-4,
     "mode": "rel", "radius": 128, "tile_syms": 512},
    {"shape": [6, 24, 40], "dtype": "f32", "seed": 102, "eb": 1e-3,
     "mode": "abs", "radius": 128, "tile_syms": 512},
    {"shape": [48, 64], "dtype": "bf16", "seed": 103, "eb": 1e-3,
     "mode": "rel", "radius": 128, "tile_syms": 512},
    {"shape": [5, 20, 36], "dtype": "f16", "seed": 104, "eb": 1e-3,
     "mode": "rel", "radius": 128, "tile_syms": 512},
]


def main() -> int:
    cases = []
    for spec in SPECS:
        _, codec, c = _golden_case(spec)
        two = np.asarray(codec.decompress(c))
        fus = np.asarray(Codec(codec.config.replace(fused=True))
                         .decompress(c))
        assert fus.tobytes() == two.tobytes(), spec
        n_outl = int((np.asarray(c.outlier_pos) >= 0).sum())
        assert n_outl > 0, spec
        cases.append({
            "spec": spec,
            "compressed_sha256": _compressed_digest(c),
            "reconstruction_sha256":
                hashlib.sha256(two.tobytes()).hexdigest(),
            "n_outliers": n_outl,
            "compressed_bytes": int(c.compressed_bytes),
        })
    out = {"format": 1,
           "note": "regenerate with tools/make_fused_golden.py; any drift "
                   "in these digests is a cross-version compressed-bytes "
                   "or reconstruction regression",
           "cases": cases}
    with open(GOLDEN_PATH, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {len(cases)} golden cases -> {GOLDEN_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
