#!/usr/bin/env python
"""Documentation smoke checks: links, code pointers, runnable snippets.

Run from the repo root (CI's docs job does):

    python tools/check_docs.py

Three checks, so documentation drift fails the build instead of a reader:

1. **Relative links** in ``README.md`` and every ``docs/*.md`` must point
   at files that exist (external http(s)/mailto links are not fetched).
2. **Code pointers** of the form ``path/to/file.py:symbol`` in
   ``docs/decoder.md``, ``docs/encoder.md`` and ``docs/serving.md`` must
   name an existing file under ``src/repro/`` that actually defines the
   symbol.
3. **Fenced ```python blocks** in ``docs/api.md``, ``docs/decoder.md``,
   ``docs/encoder.md`` and ``docs/serving.md`` are executed (each block
   standalone, ``src/`` on the path), so the examples keep working
   against the real API.

Stdlib only; exits non-zero with a list of failures.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src"
LINK_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
SNIPPET_FILES = [ROOT / "docs" / "api.md", ROOT / "docs" / "decoder.md",
                 ROOT / "docs" / "encoder.md", ROOT / "docs" / "serving.md",
                 ROOT / "docs" / "distributed.md"]
POINTER_FILES = [ROOT / "docs" / "decoder.md", ROOT / "docs" / "encoder.md",
                 ROOT / "docs" / "serving.md",
                 ROOT / "docs" / "distributed.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
POINTER_RE = re.compile(r"`([\w./]+\.py):([A-Za-z_]\w*)`")


def check_links(errors: list) -> int:
    n = 0
    for md in LINK_FILES:
        for target in LINK_RE.findall(md.read_text()):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # external scheme
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue                                    # pure anchor
            n += 1
            if not (md.parent / path).exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return n


def check_pointers(errors: list) -> int:
    sym_re = "(?:def|class)\\s+{s}\\b|^\\s*{s}\\s*[:=]"
    n = 0
    for md in POINTER_FILES:
        for path, sym in POINTER_RE.findall(md.read_text()):
            n += 1
            if "/" in path:
                candidates = [SRC / "repro" / path]
            else:   # bare filename: resolve within src/repro
                candidates = sorted((SRC / "repro").rglob(path))
            hit = next((c for c in candidates if c.exists()), None)
            if hit is None:
                errors.append(f"{md.relative_to(ROOT)}: pointer names "
                              f"missing file {path!r}")
                continue
            if not re.search(sym_re.format(s=re.escape(sym)),
                             hit.read_text(), re.M):
                errors.append(f"{md.relative_to(ROOT)}: {path}:{sym} -- "
                              f"symbol not found in "
                              f"{hit.relative_to(ROOT)}")
    return n


def check_snippets(errors: list) -> int:
    sys.path.insert(0, str(SRC))
    n = 0
    for md in SNIPPET_FILES:
        for i, block in enumerate(FENCE_RE.findall(md.read_text())):
            n += 1
            label = f"{md.relative_to(ROOT)} python block #{i + 1}"
            try:
                exec(compile(block, label, "exec"), {"__name__": f"doc_{i}"})
            except Exception as e:
                errors.append(f"{label}: {type(e).__name__}: {e}")
    return n


def main() -> int:
    errors: list = []
    counts = (check_links(errors), check_pointers(errors),
              check_snippets(errors))
    print(f"checked {counts[0]} links, {counts[1]} code pointers, "
          f"{counts[2]} snippets")
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
