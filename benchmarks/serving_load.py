"""Serving load: continuous batching + prefix sharing vs per-session paging.

The "many concurrent sessions" scenario from docs/serving.md, measured:
a corpus of offloaded KV blocks (hot shared prompt prefix + per-session
unique blocks) is replayed with Poisson arrivals in three modes --

  * **baseline** -- each session demand-pages its blocks synchronously on
    its own thread (``KVPager.fetch`` per block; the shared prefix is
    re-decoded by every session);
  * **sched_serial** -- the ``DecodeScheduler`` with ``overlap=False``:
    batching-window coalescing + prefix sharing, but stage and decode on
    one thread (the double-buffering ablation);
  * **sched_overlap** -- the full scheduler: tick N+1's host stage runs
    on the I/O thread while tick N decodes.

The ``us`` column is **p99 time-to-first-token** (the serving-tail metric
the scheduler exists to improve); derived columns carry p50, decode
dispatches per block request, and the scheduler's sharing counters.
Structural invariants (decode-once, dispatch reduction) are asserted by
``repro.serving.loadgen --check`` in CI, not here -- the benchmark is the
timing record.
"""

from __future__ import annotations

import tempfile

from repro.serving import build_corpus, run_load


def run(quick: bool = False):
    n_sessions = 24 if quick else 48
    rate = 400.0
    rows = []
    with tempfile.TemporaryDirectory(prefix="bench_serving_") as d:
        corpus = build_corpus(d, n_sessions=n_sessions, prefix_blocks=4,
                              unique_blocks=1, tokens_per_block=8, seed=0)
        tag = f"serving/s{n_sessions}"

        base = run_load(corpus, mode="baseline", rate_per_s=rate, seed=0)
        rows.append((
            f"{tag}/baseline", base["ttft"]["p99_ms"] * 1e3,
            f"p50_ms={base['ttft']['p50_ms']:.1f};"
            f"dispatch_per_req={base['dispatches_per_request']:.3f};"
            f"wall_s={base['wall_s']:.2f}"))

        for label, overlap in (("sched_serial", False),
                               ("sched_overlap", True)):
            r = run_load(corpus, mode="scheduler", rate_per_s=rate, seed=0,
                         overlap=overlap)
            st = r["scheduler"]
            rows.append((
                f"{tag}/{label}", r["ttft"]["p99_ms"] * 1e3,
                f"p50_ms={r['ttft']['p50_ms']:.1f};"
                f"dispatch_per_req={r['dispatches_per_request']:.3f};"
                f"prefix_hits={st['prefix_hits']};"
                f"coalesced={st['coalesced_requests']};"
                f"p99_speedup={base['ttft']['p99_ms'] / max(r['ttft']['p99_ms'], 1e-9):.2f}x"))
    return rows
