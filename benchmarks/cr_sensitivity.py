"""Paper Fig. 2: decoder throughput vs error bound (compressibility).

Larger eb => higher compression ratio => more symbols per stream byte; the
paper shows naive fine-grained decoders collapsing there while the
staged-write versions hold."""

from __future__ import annotations

from benchmarks import common as Cm
from benchmarks import datasets as DS
from benchmarks import tpu_model as TM

EBS = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2]


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    x, _ = DS.make_dataset("HACC", n)
    ebs = EBS[::2] if quick else EBS
    for eb in ebs:
        c = Cm.compress_ds(x, eb=eb)
        qb = c.quant_code_bytes
        for v in ("ori_gap", "opt_gap", "ori_selfsync", "opt_selfsync"):
            fn = Cm.make_variant(c, v)
            t = Cm.timeit(fn)
            rows.append((f"fig2/HACC/eb={eb:g}/{v}", t * 1e6,
                         f"cpu_GBps={Cm.gbps(qb, t):.3f};"
                         f"tpu_GBps={TM.variant_gbps(c, v):.1f};"
                         f"ratio={c.ratio:.2f}"))
    return rows
