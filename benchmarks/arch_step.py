"""Framework bench: per-arch reduced-config train & decode step wall time."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as Cm
from repro import configs
from repro.models import decode as D
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw


def run(quick: bool = False):
    rows = []
    archs = ["qwen3-0.6b", "rwkv6-3b", "qwen2-moe-a2.7b"] if quick \
        else list(configs.REGISTRY)
    for arch in archs:
        cfg = configs.get_config(arch).reduced()
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                  cfg.vocab)
        batch = {"tokens": toks,
                 "labels": jnp.roll(toks, -1, 1).at[:, -1].set(-1)}
        if cfg.family == "vlm":
            batch["extra_embeds"] = jnp.zeros((2, 8, cfg.d_model), cfg.cdt)
            batch["labels"] = jnp.concatenate(
                [jnp.full((2, 8), -1, jnp.int32), batch["labels"]], 1)
        elif cfg.family == "encdec":
            batch["extra_embeds"] = jnp.zeros((2, cfg.encoder_seq,
                                               cfg.d_model), cfg.cdt)
        ocfg = adamw.AdamWConfig()
        step = jax.jit(S.make_train_step(cfg, ocfg))
        opt = adamw.init(params, ocfg)
        t_train = Cm.timeit(lambda: step(params, opt, batch))
        rows.append((f"arch_step/{arch}/train", t_train * 1e6,
                     f"toks_per_s={2 * 64 / t_train:.0f}"))

        cache = D.init_cache(cfg, 2, 64)
        serve = jax.jit(S.make_serve_step(cfg))
        tok = toks[:, :1]
        t_dec = Cm.timeit(lambda: serve(params, tok, cache, jnp.int32(0)))
        rows.append((f"arch_step/{arch}/decode", t_dec * 1e6,
                     f"toks_per_s={2 / t_dec:.0f}"))
    return rows
