"""Paper Fig. 4: end-to-end cuSZ decompression (Huffman decode + inverse
Lorenzo), baseline vs optimized decoders.  GB/s relative to the original
dataset bytes, as in the paper."""

from __future__ import annotations

from benchmarks import common as Cm
from benchmarks import datasets as DS
from repro.core import api


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = list(DS.PAPER_RATIOS)[:3] if quick else list(DS.PAPER_RATIOS)
    for name in names:
        x, _ = DS.make_dataset(name, n)
        c = Cm.compress_ds(x)
        orig = c.original_bytes

        base_fn, _ = Cm.decode_baseline_cusz(c)
        import jax.numpy as jnp
        from repro.core.sz import lorenzo

        def e2e_base():
            codes = base_fn().reshape(-1)[: c.n_symbols]
            return lorenzo.dequantize(codes.reshape(c.shape), c.outlier_pos,
                                      c.outlier_val, c.eb, c.shape)

        t_base = Cm.timeit(e2e_base)
        rows.append((f"fig4/{name}/baseline", t_base * 1e6,
                     f"GBps={Cm.gbps(orig, t_base):.3f};speedup=1.00"))
        for method in ("selfsync", "gap"):
            def e2e(method=method):
                return api.decompress(c, method=method)

            t = Cm.timeit(e2e)
            rows.append((f"fig4/{name}/opt_{method}", t * 1e6,
                         f"GBps={Cm.gbps(orig, t):.3f};"
                         f"speedup={t_base / t:.2f}"))
    return rows
