"""Sharded restore throughput vs device count (docs/distributed.md).

A fixed 8-shard mesh-sharded checkpoint is written once; restore is then
timed at 1/2/4/8 "hosts" on a forced-8-device subprocess
(``launch.mesh.forced_host_devices_env``, single-threaded devices so
scaling reflects device count, not the intra-op thread pool).

What is timed is the per-host critical path
(``ShardedRestorer.decode_shards``): with H hosts each decodes its own
8/H shard archives concurrently and places the tiles on its devices, so
the restore wall-clock is one host's share and the *aggregate* decode
throughput scales with H.  Shares are equal-sized (equal tile grids), so
host 0's share is the critical path.  A full ``restore()`` into target
``NamedSharding``s on the 8-device mesh is also timed (``full_mesh`` row)
and its sharding landing asserted.

Run via ``benchmarks.run --only sharded`` (suite key ``"sharded"``); the
1->8 device rows are recorded in ``BENCH_baseline.json`` and join the CI
perf gate.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEVICES = 8
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(n: int = 1 << 19, quick: bool = False):
    from repro.launch.mesh import forced_host_devices_env
    env = forced_host_devices_env(N_DEVICES, single_threaded=True,
                                  base_env=os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.sharded_restore", "--worker",
           "--n", str(n)]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, cwd=_ROOT, env=env, capture_output=True,
                          text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded-restore worker failed:\n{proc.stderr}")
    # The worker prints one JSON document on its last stdout line.
    rows = json.loads(proc.stdout.strip().splitlines()[-1])
    return [(name, us, derived) for name, us, derived in rows]


# ---------------------------------------------------------------------------
# worker (runs under forced host devices; jax imported only here)
# ---------------------------------------------------------------------------


def _timeit(fn, repeats: int = 3) -> float:
    import time
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def _worker(n: int, quick: bool) -> list:
    import tempfile

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks import datasets as DS
    from repro.core import Codec, CodecConfig
    from repro.distributed import ShardedRestorer, ShardedWriter
    from repro.launch.mesh import make_host_mesh

    devs = jax.devices()
    assert len(devs) == N_DEVICES, f"expected {N_DEVICES} forced devices"
    names = ["HACC", "CESM"] if quick else ["HACC", "CESM", "Nyx", "EXAALT"]
    codec = Codec(CodecConfig(eb=1e-3, mode="rel"))
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "sharded")
        total_bytes = 0
        with ShardedWriter(ckpt, {"data": N_DEVICES}, codec=codec,
                           n_shards=N_DEVICES) as sw:
            for name in names:
                x, _ = DS.make_dataset(name, n)
                x = x.reshape(N_DEVICES * 64, -1)    # tile rows evenly
                total_bytes += x.nbytes
                sw.add(f"params.{name}", x, P("data"))
        restorer = ShardedRestorer(ckpt, codec=codec)

        # Full restore into target shardings on the whole 8-device mesh;
        # warms the plan cache for every timed run below.
        mesh = make_host_mesh(N_DEVICES, 1)
        targets = {e: NamedSharding(mesh, P("data"))
                   for e in restorer.names}
        out = restorer.restore(targets)
        for e, arr in out.items():
            assert len(arr.addressable_shards) == N_DEVICES, e
        t_full = _timeit(lambda: restorer.restore(targets))
        rows.append(["sharded/restore/full_mesh", t_full * 1e6,
                     f"GBps={total_bytes / t_full / 1e9:.3f};"
                     f"shards={N_DEVICES};entries={len(names)}"])

        # Per-host critical path at 1/2/4/8 hosts: host 0 decodes its
        # 8/H-shard share onto its devices; aggregate = total bytes over
        # that wall-clock (all hosts run concurrently, shares are equal).
        for hosts in (1, 2, 4, 8):
            share = N_DEVICES // hosts
            local = devs[:share]
            t = _timeit(lambda: restorer.decode_shards(range(share),
                                                       devices=local))
            rows.append([f"sharded/restore/d{hosts}", t * 1e6,
                         f"GBps={total_bytes / t / 1e9:.3f};hosts={hosts};"
                         f"shards_per_host={share}"])
        stats = dict(restorer.stats)
    rows.append(["sharded/restore/stats", 0.0,
                 f"tiles_decoded={stats['tiles_decoded']};"
                 f"shards_opened={stats['shards_opened']}"])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--n", type=int, default=1 << 19)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.worker:
        print(json.dumps(_worker(a.n, a.quick)))
    else:
        for r in run(a.n, a.quick):
            print(r)
