"""Analytic TPU-v5e throughput model for the decoder variants.

CPU wall-clock cannot reproduce the paper's GPU-parallel speedups (a
sequential per-chunk scan is cache-friendly on one x86 core; the
fine-grained decoders' advantage IS massive parallelism).  This model maps
each variant's work structure onto v5e resources, with every constant stated:

  * VPU: 8 sublanes x 128 lanes @ 0.94 GHz -> 1024 decode lanes
  * per-codeword decode cost: 2 dynamic gathers (unit fetch + LUT) at ~8
    cycles each + ~8 ALU ops = 24 cycles, vectorized across lanes
  * HBM: 819 GB/s; phase time = max(compute-lane time, bytes / bw)
  * window iterations stop at the slowest *active* lane (early exit) or at
    the worst case 128 (no early exit) -- the paper's `__all_sync` effect
  * "ori" (unstaged) variants pay the padded (n_subseq x 128 x 2 B) write
    plus compaction read -- the uncoalesced-write analogue (DESIGN.md §3)

The model is used for the derived `tpu_GBps` column in Table V / Fig. 2 and
is validated against the paper's *relative* speedup structure in
EXPERIMENTS.md §Paper-validation.
"""

from __future__ import annotations

import dataclasses

import numpy as np

LANES = 8 * 128
FREQ = 0.94e9
CYCLES_PER_SYMBOL = 24.0
HBM = 819e9
SUBSEQ_BITS = 128
MAX_SYMS = 128


@dataclasses.dataclass
class StreamStats:
    n_symbols: int
    n_subseq: int
    total_bits: int
    counts: np.ndarray          # symbols per subsequence
    sync_rounds: int = 2        # observed average (paper: ~2 subseqs)

    @classmethod
    def of(cls, compressed):
        c = np.asarray(compressed.stream.counts)
        return cls(
            n_symbols=compressed.n_symbols,
            n_subseq=int(compressed.stream.gaps.shape[0]),
            total_bits=int(compressed.stream.total_bits),
            counts=c,
        )


def _lane_time(stats: StreamStats, iters_per_window: float) -> float:
    waves = int(np.ceil(stats.n_subseq / LANES))
    return waves * iters_per_window * CYCLES_PER_SYMBOL / FREQ


def _window_iters(stats: StreamStats, early_exit: bool) -> float:
    if not early_exit:
        return MAX_SYMS
    # per-wave max over active lanes ~ p99.9 of the count distribution
    return float(np.quantile(stats.counts, 0.999)) if len(stats.counts) \
        else MAX_SYMS


def decode_write_time(stats: StreamStats, staged: bool,
                      early_exit: bool = True) -> float:
    compute = _lane_time(stats, _window_iters(stats, early_exit))
    stream_bytes = stats.total_bits / 8
    out_bytes = 2 * stats.n_symbols
    if staged:
        mem = (stream_bytes + out_bytes) / HBM
    else:
        padded = stats.n_subseq * MAX_SYMS * 2
        mem = (stream_bytes + padded * 2 + out_bytes) / HBM
    return max(compute, mem)


def count_phase_time(stats: StreamStats) -> float:
    compute = _lane_time(stats, _window_iters(stats, True))
    mem = (stats.total_bits / 8) / HBM
    return max(compute, mem)


def sync_phase_time(stats: StreamStats, early_exit: bool) -> float:
    rounds = stats.sync_rounds if early_exit else 32  # subseqs_per_seq
    return rounds * count_phase_time(stats)


def variant_time(compressed, variant: str) -> float:
    s = StreamStats.of(compressed)
    if variant == "baseline_cusz":
        # thread-per-chunk: 16384-symbol sequential chunks; lanes idle when
        # chunks < LANES (the coarse-grained underutilization the paper
        # identifies in §III-A)
        chunk = 16384
        n_chunks = int(np.ceil(s.n_symbols / chunk))
        waves = int(np.ceil(n_chunks / LANES))
        compute = waves * chunk * CYCLES_PER_SYMBOL / FREQ
        mem = (s.total_bits / 8 + 2 * s.n_symbols) / HBM
        return max(compute, mem)
    if variant == "ori_selfsync":
        return sync_phase_time(s, False) + count_phase_time(s) + \
            decode_write_time(s, staged=False, early_exit=False)
    if variant == "opt_selfsync":
        return sync_phase_time(s, True) + count_phase_time(s) + \
            decode_write_time(s, staged=True)
    if variant == "ori_gap":
        return count_phase_time(s) + decode_write_time(s, staged=False)
    if variant in ("opt_gap", "tuned_gap"):
        # tuned: per-class tile sizing removes the provisioning slack; model
        # as staged decode-write with per-class optimal iteration counts
        return count_phase_time(s) + decode_write_time(s, staged=True)
    raise ValueError(variant)


def variant_gbps(compressed, variant: str) -> float:
    return compressed.quant_code_bytes / variant_time(compressed, variant) \
        / 1e9
