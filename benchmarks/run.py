"""Benchmark harness entry point -- one table per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  All timings are CPU wall-clock of
the jit'd reference pipelines (this container has no TPU; the Pallas kernels
run the same phases and are validated in interpret mode by tests/).
TPU-target numbers are derived analytically in EXPERIMENTS.md §Roofline from
the dry-run artifacts (see benchmarks/roofline.py).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableV,...]
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of datasets / sizes (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma list: tableI,tableII,tableIV,tableV,"
                         "fig2,fig4,batch,store,arch,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (arch_step, batch_decode, compression_ratio,
                            cr_sensitivity, decode_throughput,
                            decoder_phases, e2e_decompression, roofline,
                            shmem_tuning, store_throughput)

    suites = [
        ("tableV", decode_throughput.run),
        ("tableII", decoder_phases.run),
        ("tableIV", compression_ratio.run),
        ("tableI", shmem_tuning.run),
        ("fig2", cr_sensitivity.run),
        ("fig4", e2e_decompression.run),
        ("batch", batch_decode.run),
        ("store", store_throughput.run),
        ("arch", arch_step.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    for key, fn in suites:
        if only and key not in only:
            continue
        try:
            if key in ("arch", "roofline"):
                rows = fn(quick=args.quick)
            else:
                rows = fn(quick=args.quick)
        except Exception as e:  # keep the harness robust: report and go on
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
