"""Benchmark harness entry point -- one table per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  All timings are CPU wall-clock of
the jit'd reference pipelines (this container has no TPU; the Pallas kernels
run the same phases and are validated in interpret mode by tests/).
TPU-target numbers are derived analytically in EXPERIMENTS.md §Roofline from
the dry-run artifacts (see benchmarks/roofline.py).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableV,...]
                                               [--record BENCH_tag.json]
                                               [--compare BENCH_old.json]

``--record`` writes the rows to a JSON file so runs can be kept as a
trajectory (convention: ``BENCH_<tag>.json``, e.g. one per PR);
``--compare`` reloads such a file and appends a ``vs_baseline`` speedup
column for every row name present in both runs.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of datasets / sizes (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma list: tableI,tableII,tableIV,tableV,"
                         "fig2,fig4,batch,store,fused,serving,sharded,"
                         "arch,roofline")
    ap.add_argument("--record", default=None, metavar="BENCH_tag.json",
                    help="write rows to a JSON trajectory file")
    ap.add_argument("--compare", default=None, metavar="BENCH_old.json",
                    help="append vs_baseline speedups from a recorded run")
    ap.add_argument("--gate", type=float, default=None, metavar="FACTOR",
                    help="with --compare: exit 1 if any row is slower than "
                         "FACTOR x its baseline (CI perf gate; pick FACTOR "
                         "well above timer noise, e.g. 2.5)")
    args = ap.parse_args()
    if args.gate is not None and not args.compare:
        ap.error("--gate requires --compare")
    only = set(args.only.split(",")) if args.only else None

    baseline = {}
    if args.compare:
        with open(args.compare) as f:
            baseline = {r[0]: float(r[1]) for r in json.load(f)["rows"]}

    from benchmarks import (arch_step, batch_decode, compression_ratio,
                            cr_sensitivity, decode_throughput,
                            decoder_phases, e2e_decompression,
                            encode_throughput, fused_decode, roofline,
                            serving_load, sharded_restore, shmem_tuning,
                            store_throughput)

    suites = [
        ("tableV", decode_throughput.run),
        ("tableII", decoder_phases.run),
        ("tableIV", compression_ratio.run),
        ("tableI", shmem_tuning.run),
        ("fig2", cr_sensitivity.run),
        ("fig4", e2e_decompression.run),
        ("batch", batch_decode.run),
        ("store", store_throughput.run),
        ("fused", fused_decode.run),
        ("encode", encode_throughput.run),
        ("serving", serving_load.run),
        ("sharded", sharded_restore.run),
        ("arch", arch_step.run),
        ("roofline", roofline.run),
    ]
    all_rows = []
    regressions = []
    print("name,us_per_call,derived")
    for key, fn in suites:
        if only and key not in only:
            continue
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # keep the harness robust: report and go on
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            if args.gate is not None:
                regressions.append((f"{key}/ERROR", 0.0, 0.0))
            continue
        for name, us, derived in rows:
            # Record the un-annotated row: a trajectory file must not bake
            # in speedups relative to whatever --compare happened to load.
            all_rows.append([name, us, derived])
            if name in baseline and us > 0:
                derived = f"{derived};vs_baseline={baseline[name] / us:.2f}"
                if args.gate is not None and us > args.gate * baseline[name]:
                    regressions.append((name, us, baseline[name]))
            print(f"{name},{us:.1f},{derived}", flush=True)

    if args.record:
        with open(args.record, "w") as f:
            json.dump({"argv": sys.argv[1:], "rows": all_rows}, f, indent=1)

    if regressions:
        print(f"PERF GATE FAILED ({len(regressions)} rows > "
              f"{args.gate:g}x baseline):", file=sys.stderr)
        for name, us, base_us in regressions:
            print(f"  {name}: {us:.1f}us vs baseline {base_us:.1f}us",
                  file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
