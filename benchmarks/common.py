"""Timing + decoder-variant helpers shared by the benchmark tables."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Codec, CodecConfig
from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time (s) of jit'd fn; blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def luts(book):
    return jnp.asarray(book.dec_sym), jnp.asarray(book.dec_len)


# ---------------------------------------------------------------------------
# The five decoder variants of paper Table V
# ---------------------------------------------------------------------------


def decode_baseline_cusz(compressed, chunk_symbols: int = 16384):
    """cuSZ naive coarse-grained decoder (per-chunk sequential)."""
    book = compressed.codebook
    ds, dl = luts(book)
    syms = np.asarray(
        hd.decode_sequential(jnp.asarray(compressed.stream.units), ds, dl,
                             n_symbols=compressed.n_symbols,
                             max_len=book.max_len))
    ch = he.encode_chunked(syms, book.enc_code, book.enc_len,
                           chunk_symbols=chunk_symbols)

    def run():
        return hd.decode_chunked(ch["units"], ch["chunk_bits"],
                                 ch["chunk_syms"], ds, dl,
                                 max_len=book.max_len,
                                 chunk_symbols=chunk_symbols)

    return run, ch["stored_bytes"]


# (method, strategy, early_exit) per paper Table V variant -- each variant
# is nothing but a CodecConfig (plus the self-sync early-exit toggle).
_VARIANTS = {
    "ori_selfsync": ("selfsync", "padded", False),
    "opt_selfsync": ("selfsync", "tile", True),
    "ori_gap": ("gap", "padded", True),
    "opt_gap": ("gap", "tile", True),
    "tuned_gap": ("gap", "tuned", True),
}


def variant_codec(variant: str, backend: str = "ref") -> Codec:
    """The ``Codec`` whose config IS the named paper Table V variant."""
    if variant not in _VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; valid variants: "
                         f"{sorted(_VARIANTS)}")
    method, strategy, _ = _VARIANTS[variant]
    return Codec(CodecConfig(method=method, strategy=strategy,
                             backend=backend))


def make_variant(compressed, variant: str, backend: str = "ref"):
    """variant in {ori_selfsync, opt_selfsync, ori_gap, opt_gap, tuned_gap}.

    "ori_*"  = padded per-subsequence writes + gather compaction (the
               original decoders' uncoalesced-write cost structure) and, for
               self-sync, worst-case fixed sync rounds;
    "opt_*"  = VMEM-staged output tiles (paper Alg. 1) + early-exit sync;
    "tuned_*" = per-CR-class tiles (paper Alg. 2), plan prebuilt (the tuner's
               classify/sort cost is timed separately in tableII).

    Every variant is one ``CodecConfig`` (method x strategy x backend)
    driving ``Codec.decode``; no strategy/backend kwarg plumbing.
    """
    codec = variant_codec(variant, backend)   # validates the variant name
    _, strategy, early_exit = _VARIANTS[variant]
    c = compressed
    stream, book, n = c.stream, c.codebook, c.n_symbols
    plan = codec.build_plan(stream, book) if strategy == "tuned" else None

    def run():
        return codec.decode(stream, book, n, plan=plan,
                            early_exit=early_exit)

    return run


def gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def compress_ds(x, eb: "float | None" = None):
    cfg = CodecConfig() if eb is None else CodecConfig(eb=eb)
    return Codec(cfg).compress(x)
