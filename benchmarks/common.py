"""Timing + decoder-variant helpers shared by the benchmark tables."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he
from repro.core.huffman import tuning
from repro.core.huffman.bits import SUBSEQ_BITS


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time (s) of jit'd fn; blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def luts(book):
    return jnp.asarray(book.dec_sym), jnp.asarray(book.dec_len)


# ---------------------------------------------------------------------------
# The five decoder variants of paper Table V
# ---------------------------------------------------------------------------


def decode_baseline_cusz(compressed, chunk_symbols: int = 16384):
    """cuSZ naive coarse-grained decoder (per-chunk sequential)."""
    book = compressed.codebook
    ds, dl = luts(book)
    syms = np.asarray(
        hd.decode_sequential(jnp.asarray(compressed.stream.units), ds, dl,
                             n_symbols=compressed.n_symbols,
                             max_len=book.max_len))
    ch = he.encode_chunked(syms, book.enc_code, book.enc_len,
                           chunk_symbols=chunk_symbols)

    def run():
        return hd.decode_chunked(ch["units"], ch["chunk_bits"],
                                 ch["chunk_syms"], ds, dl,
                                 max_len=book.max_len,
                                 chunk_symbols=chunk_symbols)

    return run, ch["stored_bytes"]


def make_variant(compressed, variant: str):
    """variant in {ori_selfsync, opt_selfsync, ori_gap, opt_gap, tuned_gap}.

    "ori_*"  = padded per-subsequence writes + gather compaction (the
               original decoders' uncoalesced-write cost structure) and, for
               self-sync, worst-case fixed sync rounds;
    "opt_*"  = VMEM-staged output tiles (paper Alg. 1) + early-exit sync.
    """
    c = compressed
    book = c.codebook
    ds, dl = luts(book)
    n = c.n_symbols
    stream = c.stream

    if variant == "ori_selfsync":
        def run():
            return hd.decode_selfsync(stream, ds, dl, book.max_len, n,
                                      use_tiles=False, early_exit=False)
    elif variant == "opt_selfsync":
        def run():
            return hd.decode_selfsync(stream, ds, dl, book.max_len, n,
                                      use_tiles=True, early_exit=True)
    elif variant == "ori_gap":
        def run():
            return hd.decode_gap_array(stream, ds, dl, book.max_len, n,
                                       use_tiles=False)
    elif variant == "opt_gap":
        def run():
            return hd.decode_gap_array(stream, ds, dl, book.max_len, n,
                                       use_tiles=True)
    elif variant == "tuned_gap":
        starts = hd.gap_starts(stream)
        nss = stream.gaps.shape[0]
        bnds = jnp.arange(nss, dtype=jnp.int32) * SUBSEQ_BITS
        _, counts = hd.subseq_scan(jnp.asarray(stream.units), ds, dl, starts,
                                   bnds + SUBSEQ_BITS, stream.total_bits,
                                   book.max_len)

        def run():
            return tuning.decode_tuned(stream, ds, dl, book.max_len, n,
                                       starts, counts)
    else:
        raise ValueError(variant)
    return run


def gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def compress_ds(x, eb=1e-3):
    return api.compress(x, eb=eb, mode="rel")
