"""Paper Table I + Fig. 3: tuned buffer size vs brute-force sweep.

The tunable is the decode-write tile size (the VMEM staging buffer).  For
each dataset we brute-force tile sizes 1024..8192 (step 512, as in the
paper) and compare the online tuner's per-class dispatch, including its own
overhead.  Derived: best/worst brute-force GB/s, tuned GB/s, % differences.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common as Cm
from benchmarks import datasets as DS
from repro.core.huffman import decode as hd
from repro.core.huffman import pipeline as hp
from repro.core.huffman.bits import SUBSEQ_BITS

SIZES = list(range(1024, 8193, 512))


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = ["HACC", "EXAALT"] if quick else list(DS.PAPER_RATIOS)
    sizes = SIZES[::4] if quick else SIZES
    for name in names:
        x, _ = DS.make_dataset(name, n)
        c = Cm.compress_ds(x)
        book = c.codebook
        ds, dl = Cm.luts(book)
        stream = c.stream
        units = jnp.asarray(stream.units)
        nss = stream.gaps.shape[0]
        bnds = jnp.arange(nss, dtype=jnp.int32) * SUBSEQ_BITS
        starts = bnds + stream.gaps.astype(jnp.int32)
        _, counts = hd.subseq_scan(units, ds, dl, starts, bnds + SUBSEQ_BITS,
                                   stream.total_bits, book.max_len)
        offsets = hd.output_offsets(counts)
        qb = c.quant_code_bytes

        per_size = {}
        for tile in sizes:
            ss_max = hp.ss_max_for_tile(tile, book.max_len)
            t = Cm.timeit(lambda tile=tile, ss=ss_max: hd.decode_write_tiles(
                units, ds, dl, starts, bnds + SUBSEQ_BITS, offsets,
                stream.total_bits, book.max_len, c.n_symbols, tile, ss))
            per_size[tile] = t
        best = min(per_size, key=per_size.get)
        worst = max(per_size, key=per_size.get)

        t_tuned = Cm.timeit(lambda: hp.execute_tuned(
            stream, ds, dl, book.max_len, c.n_symbols, starts, counts))
        t_plan = Cm.timeit(lambda: hp.sort_by_class(hp.classify(
            hp.sequence_ratios(stream.seq_counts,
                               stream.subseqs_per_seq))))

        g_best = Cm.gbps(qb, per_size[best])
        g_worst = Cm.gbps(qb, per_size[worst])
        g_tuned = Cm.gbps(qb, t_tuned + t_plan)
        rows.append((f"tableI/{name}/best_bruteforce", per_size[best] * 1e6,
                     f"GBps={g_best:.3f};tile={best}"))
        rows.append((f"tableI/{name}/worst_bruteforce", per_size[worst] * 1e6,
                     f"GBps={g_worst:.3f};tile={worst}"))
        rows.append((f"tableI/{name}/tuned_with_overhead",
                     (t_tuned + t_plan) * 1e6,
                     f"GBps={g_tuned:.3f};"
                     f"vs_best_pct={100 * (g_best - g_tuned) / g_best:.1f};"
                     f"vs_worst_pct={100 * (g_tuned - g_worst) / max(g_worst, 1e-9):.1f}"))
    return rows
