"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.jsonl")


def load(path: str = RESULTS, multi_pod: bool = False):
    cells = {}
    try:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok") and r.get("multi_pod") == multi_pod:
                    cells[(r["arch"], r["shape"])] = r
    except FileNotFoundError:
        pass
    return cells


def run(quick: bool = False):
    rows = []
    cells = load()
    for (arch, shape), r in sorted(cells.items()):
        rl = r["roofline"]
        c = r["cost"]
        rows.append((
            f"roofline/{arch}/{shape}",
            rl["bound_step_s"] * 1e6,
            f"t_c={rl['t_compute_s']:.4f};t_m={rl['t_memory_s']:.4f};"
            f"t_l={rl['t_collective_s']:.4f};dom={rl['dominant']};"
            f"model_over_hlo={c.get('model_over_hlo', 0):.3f};"
            f"peak_gib={r['mem']['peak_per_device_gib']}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run python -m repro.launch.dryrun --all first"))
    return rows
