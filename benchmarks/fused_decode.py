"""Fused decode→dequantize→reconstruct vs the two-pass decompression path.

The two-pass path materializes the full uint16 quant-code array in HBM
between the Huffman decode-write dispatch and the Lorenzo reconstruction
(one 2 B/symbol write + one 2 B/symbol read of pure intermediate traffic);
the fused path (``CodecConfig(fused=True)``) carries the decoded symbols
through dequantization and the inverse-Lorenzo prefix sum inside the
decode-write dispatch, so that round trip disappears.  This table times
both paths over Table-V-style compression-ratio variants (CR swept via the
error bound, as in the paper's Fig. 2 sensitivity study) and reports the
intermediate-traffic accounting: ``intermediate_bytes`` is the size of the
decode→reconstruct handoff that each path moves through HBM -- always 0
for the fused path, ``2 * quant_code_bytes`` for two-pass.

Wall times are CPU timings of the jit'd reference pipelines (the Pallas
fused kernel runs the same phases; interpret mode is not timeable); the
HBM-traffic column is the quantity the paper's memory-bound analysis says
dominates on an accelerator.  Each cell also asserts fused output is
bit-exact with two-pass before timing.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common as Cm
from benchmarks import datasets as DS

from repro.core import Codec, CodecConfig
from repro.core.huffman import pipeline as hp

#: CR variants: relative error bounds spanning low-CR to high-CR regimes.
EBS = (1e-2, 1e-3, 1e-4)

#: Row count for the 2-D variant: the calibrated 1-D field viewed as a
#: (512, n/512) grid, exercising the row-carry fused epilogue.
VARIANT_ROWS = 512


def _cell(x, tag: str, eb: float, rows: list):
    c = Cm.compress_ds(x, eb=eb)
    qbytes = c.quant_code_bytes
    two = Codec(CodecConfig(eb=eb, strategy="tile"))
    fus = Codec(CodecConfig(eb=eb, strategy="tile", fused=True))
    plan = two.plan_for(c)

    be = hp.get_backend("ref")
    be.reset_stats()
    a = two.decompress(c, plan=plan)
    b = fus.decompress(c, plan=plan)
    assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
        tag   # fused must be bit-exact before it is timed
    assert be.stats["fused_fallbacks"] == 0, tag

    t2 = Cm.timeit(lambda: two.decompress(c, plan=plan))
    tf = Cm.timeit(lambda: fus.decompress(c, plan=plan))
    rows.append((f"{tag}/twopass", t2 * 1e6,
                 f"CR={c.ratio:.2f};intermediate_bytes={2 * qbytes}"))
    rows.append((f"{tag}/fused", tf * 1e6,
                 f"CR={c.ratio:.2f};intermediate_bytes=0;"
                 f"cpu_speedup={t2 / tf:.2f}"))


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = list(DS.PAPER_RATIOS)[:2] if quick else list(DS.PAPER_RATIOS)[:4]
    ebs = EBS[:2] if quick else EBS
    if quick:
        n = n // 4
    for name in names:
        x, _ = DS.make_dataset(name, n)
        for eb in ebs:
            _cell(x, f"fused/{name}/eb{eb:g}", eb, rows)

    # N-D / low-precision variants on the first dataset: the same field
    # viewed as a 2-D grid (row-carry epilogue, per-row cumsum instead of
    # one long chain) and cast to bfloat16 (f32 epilogue + final cast).
    # Both are fused-eligible, so fused_fallbacks must stay 0 here too.
    vx, _ = DS.make_dataset(names[0], n)
    x2d = np.asarray(vx)[:(len(vx) // VARIANT_ROWS) * VARIANT_ROWS]
    x2d = x2d.reshape(VARIANT_ROWS, -1)
    xbf = jnp.asarray(vx).astype(jnp.bfloat16)
    for eb in ebs:
        _cell(x2d, f"fused/{names[0]}-2d/eb{eb:g}", eb, rows)
        _cell(xbf, f"fused/{names[0]}-bf16/eb{eb:g}", eb, rows)
    return rows
