"""Surrogate scientific datasets, compressibility-matched to the paper.

The eight evaluation datasets (HACC...GAMESS) are not redistributable here;
we synthesize fields whose cuSZ compression ratio at rel-eb 1e-3 matches the
paper's Table IV by mixing an integrated-noise (Lorenzo-predictable) field
with white noise and calibrating the noise amplitude by bisection.  Sizes are
scaled (default 2 MiB per dataset) so the CPU benchmark suite stays fast;
ratios are size-invariant for stationary fields.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core import api
from repro.data.pipeline import smooth_field

# paper Table IV, "baseline cuSZ" row (rel eb = 1e-3)
PAPER_RATIOS = {
    "HACC": 3.20, "EXAALT": 2.40, "CESM": 9.06, "Nyx": 15.64,
    "Hurricane": 9.78, "QMCPack": 2.46, "RTM": 8.41, "GAMESS": 12.10,
}
# paper dataset sizes (MiB) -- used for relative weighting in summaries
PAPER_SIZES_MIB = {
    "HACC": 1071.8, "EXAALT": 951.7, "CESM": 642.7, "Nyx": 512.0,
    "Hurricane": 381.5, "QMCPack": 601.5, "RTM": 180.7, "GAMESS": 306.2,
}

DEFAULT_N = 1 << 19  # 512k floats = 2 MiB per dataset


def _field(noise_amp: float, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = smooth_field((n,), seed=seed)
    x = base + noise_amp * rng.standard_normal(n).astype(np.float32)
    return x.astype(np.float32)


def _ratio(noise_amp: float, n: int, seed: int, eb: float) -> float:
    return api.compress(_field(noise_amp, n, seed), eb=eb).ratio


@functools.lru_cache(maxsize=None)
def make_dataset(name: str, n: int = DEFAULT_N, eb: float = 1e-3,
                 tol: float = 0.08):
    """Returns (x float32[n], achieved_ratio) calibrated to PAPER_RATIOS."""
    target = PAPER_RATIOS[name]
    seed = abs(hash(name)) % (2 ** 31)
    lo, hi = 0.0, 2.0          # noise amplitude bracket
    # ratio decreases monotonically with noise
    for _ in range(18):
        mid = 0.5 * (lo + hi)
        r = _ratio(mid, n, seed, eb)
        if abs(r - target) / target < tol:
            return _field(mid, n, seed), r
        if r > target:
            lo = mid
        else:
            hi = mid
    return _field(0.5 * (lo + hi), n, seed), _ratio(0.5 * (lo + hi), n,
                                                    seed, eb)


def all_datasets(n: int = DEFAULT_N, eb: float = 1e-3):
    return {name: make_dataset(name, n, eb) for name in PAPER_RATIOS}
