"""Paper Table II: per-phase breakdown of the optimized decoders.

Phases: intra-seq sync / inter-seq sync / get-output-idx / tune / decode+write
(throughput per phase, GB/s of quantization codes)."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common as Cm
from benchmarks import datasets as DS
from repro.core.huffman import decode as hd
from repro.core.huffman import pipeline as hp
from repro.core.huffman.bits import SUBSEQ_BITS


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = ["HACC", "Nyx"] if quick else list(DS.PAPER_RATIOS)
    for name in names:
        x, _ = DS.make_dataset(name, n)
        c = Cm.compress_ds(x)
        book = c.codebook
        ds, dl = Cm.luts(book)
        stream = c.stream
        units = jnp.asarray(stream.units)
        nss = stream.gaps.shape[0]
        qb = c.quant_code_bytes
        bnds = jnp.arange(nss, dtype=jnp.int32) * SUBSEQ_BITS

        # self-sync phases
        t_intra = Cm.timeit(
            lambda: hd.selfsync_intra(units, ds, dl, stream.total_bits, nss,
                                      book.max_len, stream.subseqs_per_seq))
        start, _ = hd.selfsync_intra(units, ds, dl, stream.total_bits, nss,
                                     book.max_len, stream.subseqs_per_seq)
        t_inter = Cm.timeit(
            lambda: hd.selfsync_inter(units, ds, dl, start,
                                      stream.total_bits, book.max_len,
                                      stream.subseqs_per_seq))
        # counts / output idx (shared by gap path = its phase 1)
        t_idx = Cm.timeit(
            lambda: hd.subseq_scan(units, ds, dl, bnds + stream.gaps.astype(
                jnp.int32), bnds + SUBSEQ_BITS, stream.total_bits,
                book.max_len))
        _, counts = hd.subseq_scan(units, ds, dl,
                                   bnds + stream.gaps.astype(jnp.int32),
                                   bnds + SUBSEQ_BITS, stream.total_bits,
                                   book.max_len)
        offsets = hd.output_offsets(counts)
        ss_max = hp.ss_max_for_tile(4096, book.max_len)
        t_dw = Cm.timeit(
            lambda: hd.decode_write_tiles(
                units, ds, dl, bnds + stream.gaps.astype(jnp.int32),
                bnds + SUBSEQ_BITS, offsets, stream.total_bits, book.max_len,
                c.n_symbols, 4096, ss_max))
        # tuning overhead (classify/hist/sort/plan)
        t_tune = Cm.timeit(
            lambda: hp.sort_by_class(hp.classify(
                hp.sequence_ratios(stream.seq_counts,
                                   stream.subseqs_per_seq))))

        for phase, t in [("intra_seq_sync", t_intra),
                         ("inter_seq_sync", t_inter),
                         ("get_output_idx", t_idx),
                         ("tune_shared_mem", t_tune),
                         ("decode_and_write", t_dw)]:
            rows.append((f"tableII/{name}/{phase}", t * 1e6,
                         f"GBps={Cm.gbps(qb, t):.3f}"))
    return rows
