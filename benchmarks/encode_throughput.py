"""Write-path throughput: host encode vs the device encode pipeline.

The read path got the paper's optimizations; this table asks whether the
write path keeps up.  For each dataset/size cell it times end-to-end
``Codec.compress`` under ``encode_backend="ref"`` (the host path: float64
prequantization, numpy histogram) and ``encode_backend="jnp"`` (the
device pipeline the Pallas kernels implement: in-graph f32 quantize ->
outlier gather -> device histogram -> jit bit-pack, with only the
2*radius-entry histogram crossing to host for codebook construction).
Before timing, each cell decode-verifies the device-encoded payload
against the input within ``eb_effective`` -- the speedup is never bought
with a wrong stream.  (Byte-identity is asserted by the encode parity
matrix in tests/ on lattice-aligned inputs; on arbitrary data the f32
in-graph quantizer may tie-round a handful of codes differently from the
f64 host prequantizer, both within bound.)

GB/s is raw input bytes over wall time (the write-path twin of the
decoder tables' quant-code GB/s).  A ``compress_tree`` row times the
multi-tensor entry point the checkpoint/KV consumers actually call.  As
everywhere in this harness, timings are CPU wall-clock of the jit'd
reference pipelines; the Pallas bit-pack kernel runs the same phases and
is validated in interpret mode by tests/.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as Cm
from benchmarks import datasets as DS

from repro.core import Codec, CodecConfig
from repro.core.huffman import pipeline as hp

#: Input sizes in float32 elements (1 MiB and 4 MiB).
SIZES = (1 << 18, 1 << 20)


def _block_compress(codec, x):
    c = codec.compress(x)
    # Compressed is a host container, not a pytree: block on the arrays the
    # encode actually produced.
    jax.block_until_ready((c.stream.units, c.outlier_pos))
    return c


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    del n  # sized by SIZES: the write path is the variable here
    rows = []
    names = list(DS.PAPER_RATIOS)[:1] if quick else list(DS.PAPER_RATIOS)[:3]
    sizes = SIZES[:1] if quick else SIZES
    for name in names:
        for sz in sizes:
            x, _ = DS.make_dataset(name, sz)
            raw = x.size * 4
            cells = {}
            for backend in ("ref", "jnp"):
                codec = Codec(CodecConfig(encode_backend=backend))
                cells[backend] = (codec, _block_compress(codec, x))
            c_dev = cells["jnp"][1]
            err = float(np.max(np.abs(
                np.asarray(cells["jnp"][0].decompress(c_dev)).reshape(-1)
                - x.reshape(-1))))
            assert err <= c_dev.eb_effective, (name, sz, err)

            mib = raw // (1 << 20)
            times = {}
            for backend, (codec, c) in cells.items():
                t = Cm.timeit(lambda codec=codec: _block_compress(codec, x))
                times[backend] = t
                derived = (f"CR={c.ratio:.2f};GBps={Cm.gbps(raw, t):.3f}")
                if backend == "jnp":
                    derived += f";host_vs_device={times['ref'] / t:.2f}"
                rows.append((f"encode/{name}/{mib}MiB/{backend}",
                             t * 1e6, derived))

    # Multi-tensor write path (what checkpoint shards / KV eviction call).
    x0, _ = DS.make_dataset(names[0], sizes[0])
    tree = {"a": x0, "b": x0[: x0.size // 2] * 0.5}
    for backend in ("ref", "jnp"):
        codec = Codec(CodecConfig(encode_backend=backend))

        def run_tree(codec=codec):
            ct = codec.compress_tree(tree)
            jax.block_until_ready((ct["a"].stream.units,
                                   ct["b"].stream.units))
            return ct

        t = Cm.timeit(run_tree)
        be = hp.get_encode_backend(backend)
        be.reset_stats()
        run_tree()   # counters for exactly one tree walk
        rows.append((f"encode/compress_tree/{backend}", t * 1e6,
                     f"leaves=2;encode_dispatches="
                     f"{be.stats['encode_dispatches']};encode_fallbacks="
                     f"{be.stats['encode_fallbacks']}"))
    return rows
