"""Paper Table V: decoding throughput of the five methods x 8 datasets.

CPU wall-clock of the jit'd jnp pipelines (the Pallas kernels execute the
same phases; interpret mode is not timeable).  GB/s is relative to the
quantization-code bytes (2 B/code), exactly as the paper computes it.
Derived column: speedup over the cuSZ baseline decoder.
"""

from __future__ import annotations

from benchmarks import common as Cm
from benchmarks import datasets as DS
from benchmarks import tpu_model as TM

VARIANTS = ["ori_selfsync", "opt_selfsync", "ori_gap", "opt_gap", "tuned_gap"]


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = list(DS.PAPER_RATIOS)[:3] if quick else list(DS.PAPER_RATIOS)
    for name in names:
        x, ratio = DS.make_dataset(name, n)
        c = Cm.compress_ds(x)
        qbytes = c.quant_code_bytes

        base_fn, _ = Cm.decode_baseline_cusz(c)
        t_base = Cm.timeit(base_fn)
        tpu_base = TM.variant_gbps(c, "baseline_cusz")
        rows.append((f"tableV/{name}/baseline_cusz", t_base * 1e6,
                     f"cpu_GBps={Cm.gbps(qbytes, t_base):.3f};"
                     f"tpu_GBps={tpu_base:.1f};tpu_speedup=1.00"))
        for v in VARIANTS:
            fn = Cm.make_variant(c, v)
            t = Cm.timeit(fn)
            tg = TM.variant_gbps(c, v)
            rows.append((f"tableV/{name}/{v}", t * 1e6,
                         f"cpu_GBps={Cm.gbps(qbytes, t):.3f};"
                         f"tpu_GBps={tg:.1f};"
                         f"tpu_speedup={tg / tpu_base:.2f}"))
    return rows
