"""Store read throughput: cold vs warm opens, prefetch on vs off.

The restore-at-scale scenario: a multi-chunk ``.szt`` archive streams
through ``Archive.iter_decode``.  Three effects are measured:

  * **overlap** -- double-buffered reads (a host thread reads + CRC-checks
    chunk group N+1 while group N decodes) vs strictly serial read->decode;
  * **plan cache** -- a warm re-open skips every phase 1-3 ``build_plan``
    (dispatch counter asserted zero rebuilt plans);
  * **chunking** -- decode dispatches stay per-CR-class per group, not per
    tensor.

Throughput is reported against decoded quant-code bytes (the paper's
decoder GB/s definition).
"""

from __future__ import annotations

import os
import tempfile

from benchmarks import common as Cm
from benchmarks import datasets as DS
from repro.core import api
from repro.core.huffman import pipeline as hp
from repro.store import Archive, PlanCache, write_archive


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = ["HACC"] if quick else ["HACC", "Nyx"]
    n_chunks = 4 if quick else 8
    chunk_n = max(n // 8, 1 << 14)
    be = hp.get_backend("ref")
    for name in names:
        entries = []
        for s in range(n_chunks):
            x, _ = DS.make_dataset(name, chunk_n)
            entries.append((f"{name}.{s}",
                            api.compress(x, eb=1e-3, mode="rel"), "float32"))
        qb = sum(c.quant_code_bytes for _, c, _ in entries)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "bench.szt")
            write_archive(path, entries)
            stored = os.path.getsize(path)

            def read(cache, prefetch):
                with Archive(path, plan_cache=cache) as ar:
                    return ar.read_all(group_chunks=2, prefetch=prefetch)

            # Cold opens rebuild plans; fresh cache per call.
            t_serial = Cm.timeit(lambda: read(PlanCache(), False))
            t_overlap = Cm.timeit(lambda: read(PlanCache(), True))

            warm = PlanCache()
            read(warm, True)                     # populate
            be.reset_stats()
            t_warm = Cm.timeit(lambda: read(warm, True))
            rebuilt = be.stats["plan_builds"]

        tag = f"store/{name}/x{n_chunks}"
        rows.append((f"{tag}/cold_serial", t_serial * 1e6,
                     f"GBps={Cm.gbps(qb, t_serial):.3f};"
                     f"stored_MiB={stored / 2**20:.2f}"))
        rows.append((f"{tag}/cold_overlap", t_overlap * 1e6,
                     f"GBps={Cm.gbps(qb, t_overlap):.3f};"
                     f"speedup={t_serial / max(t_overlap, 1e-12):.2f}x"))
        rows.append((f"{tag}/warm_plan_cache", t_warm * 1e6,
                     f"GBps={Cm.gbps(qb, t_warm):.3f};"
                     f"rebuilt_plans={rebuilt}"))
    return rows
