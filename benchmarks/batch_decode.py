"""Batched multi-tensor decode: per-tensor tuned decode vs decode_batch.

The serving-scale scenario (ROADMAP north star): a checkpoint of N shards
or a KV cache of N blocks restores through the Huffman decoder.  Per-tensor
tuned decoding launches one decode-write dispatch per (tensor, CR class);
``pipeline.decode_batch`` gathers same-class sequences of ALL tensors into
one dispatch per class.  Reported: wall time of both paths and the dispatch
counts from the backend registry.
"""

from __future__ import annotations

from benchmarks import common as Cm
from benchmarks import datasets as DS
from repro.core import api
from repro.core.huffman import pipeline as hp


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = ["HACC", "Nyx"] if quick else list(DS.PAPER_RATIOS)
    shard_n = max(n // 8, 1 << 14)
    for name in names:
        # N shards of one dataset (a sharded checkpoint of that field).
        n_shards = 4 if quick else 8
        cs = []
        for s in range(n_shards):
            x, _ = DS.make_dataset(name, shard_n)
            cs.append(api.compress(x, eb=1e-3, mode="rel"))
        streams = [c.stream for c in cs]
        books = [c.codebook for c in cs]
        n_outs = [c.n_symbols for c in cs]
        plans = [hp.build_plan(s, b) for s, b in zip(streams, books)]
        be = hp.get_backend("ref")

        def run_per_tensor():
            return [hp.decode(s, b, n_o, plan=p, strategy="tuned")
                    for s, b, n_o, p in zip(streams, books, n_outs, plans)]

        def run_batched():
            return hp.decode_batch(streams, books, n_outs, plans=plans)

        be.reset_stats()
        run_per_tensor()
        d_per = be.stats["decode_write_dispatches"]
        be.reset_stats()
        run_batched()
        d_batch = be.stats["decode_write_dispatches"]

        t_per = Cm.timeit(run_per_tensor)
        t_batch = Cm.timeit(run_batched)
        qb = sum(c.quant_code_bytes for c in cs)
        rows.append((f"batch/{name}/per_tensor_x{n_shards}", t_per * 1e6,
                     f"GBps={Cm.gbps(qb, t_per):.3f};dispatches={d_per}"))
        rows.append((f"batch/{name}/decode_batch_x{n_shards}", t_batch * 1e6,
                     f"GBps={Cm.gbps(qb, t_batch):.3f};dispatches={d_batch}"))
    return rows
