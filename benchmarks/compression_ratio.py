"""Paper Table IV: compression-ratio parity of the decoding methods.

The fine-grained decoders share one stream; the gap-array method adds 1 B
per subsequence; the cuSZ coarse baseline pads every chunk to a unit
boundary.  Derived column reports ratio and the x-vs-baseline factor."""

from __future__ import annotations

import numpy as np

from benchmarks import common as Cm
from benchmarks import datasets as DS
from repro.core.huffman import encode as he


def run(n: int = DS.DEFAULT_N, quick: bool = False):
    rows = []
    names = list(DS.PAPER_RATIOS)[:3] if quick else list(DS.PAPER_RATIOS)
    for name in names:
        x, _ = DS.make_dataset(name, n)
        c = Cm.compress_ds(x)
        orig = c.original_bytes

        # shared stream cost components
        stream_bytes = int(np.ceil(int(c.stream.total_bits) / 8))
        gap_bytes = c.stream.gaps.shape[0]
        side = (8 * int((np.asarray(c.outlier_pos) >= 0).sum())
                + 2 * (1 << c.codebook.max_len))

        selfsync_total = stream_bytes + side           # no gap array stored
        gap_total = stream_bytes + gap_bytes + side

        book = c.codebook
        import jax.numpy as jnp
        from repro.core.huffman import decode as hd
        syms = np.asarray(hd.decode_sequential(
            jnp.asarray(c.stream.units), *Cm.luts(book),
            n_symbols=c.n_symbols, max_len=book.max_len))
        ch = he.encode_chunked(syms, book.enc_code, book.enc_len)
        baseline_total = ch["stored_bytes"] + side

        base_ratio = orig / baseline_total
        for method, total in [("baseline_cusz", baseline_total),
                              ("selfsync", selfsync_total),
                              ("gap_array", gap_total)]:
            r = orig / total
            rows.append((f"tableIV/{name}/{method}", 0.0,
                         f"ratio={r:.3f};vs_baseline={r / base_ratio:.3f}"))
    return rows
