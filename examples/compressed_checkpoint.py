"""Error-bounded compressed checkpoints: save a trained model with SZ-
compressed float shards, restore, verify the bound, keep training.

    PYTHONPATH=src python examples/compressed_checkpoint.py
"""

import tempfile

import jax
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw


def main():
    cfg = configs.get_config("qwen3-0.6b").reduced()
    ocfg = adamw.AdamWConfig(lr=1e-3)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=4))
    step = jax.jit(S.make_train_step(cfg, ocfg))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, ocfg)
    for s in range(10):
        params, opt, m = step(params, opt, data.batch_at(s))
    print(f"trained 10 steps, loss {float(m['loss']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        from repro.core import Codec, CodecConfig
        mgr = CheckpointManager(d, codec=Codec(CodecConfig(eb=1e-4)),
                                compress_min_size=4096)
        mgr.save(9, params, opt)
        import os
        import subprocess
        size = int(subprocess.check_output(["du", "-sb", d]).split()[0])
        raw = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params)) + \
            sum(np.asarray(x).nbytes for x in jax.tree.leaves(opt))
        print(f"checkpoint {size / 2**20:.1f} MiB vs raw {raw / 2**20:.1f} "
              f"MiB ({raw / size:.2f}x)")
        r = mgr.restore()
        key = lambda kv: jax.tree_util.keystr(kv[0])
        for (ka, a), (kb, b) in zip(
                sorted(jax.tree_util.tree_leaves_with_path(params), key=key),
                sorted(jax.tree_util.tree_leaves_with_path(r["params"]),
                       key=key)):
            err = np.abs(np.asarray(a, np.float32)
                         - np.asarray(b, np.float32)).max()
            rng_ = float(np.asarray(a, np.float32).max()
                         - np.asarray(a, np.float32).min())
            assert err <= max(1e-4 * rng_ * 1.02, 1e-7), (ka, err)
        print("restore within error bound: OK")
        p2, o2 = r["params"], r["opt"]
        for s in range(10, 13):
            p2, o2, m = step(p2, o2, data.batch_at(s))
        print(f"continued training, loss {float(m['loss']):.3f}: OK")


if __name__ == "__main__":
    main()
