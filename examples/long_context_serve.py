"""Serve with a compressed KV cache (the paper's in-memory use case).

    PYTHONPATH=src python examples/long_context_serve.py

Runs a sliding-window (h2o-danube-style) reduced model, prefills a prompt,
SZ-compresses the cache, restores it through the optimized parallel Huffman
decoder, and keeps generating.
"""

from repro.launch import serve


def main():
    out = serve.main([
        "--arch", "h2o-danube-1.8b", "--reduced",
        "--batch", "2", "--prompt-len", "48", "--gen-len", "32",
        "--compress-kv", "--kv-eb", "5e-3",
    ])
    assert out["tokens"].shape == (2, 33)
    print("generated token matrix:", out["tokens"].shape, "OK")


if __name__ == "__main__":
    main()
