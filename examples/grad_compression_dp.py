"""Gradient compression over an explicit data-parallel mesh.

    PYTHONPATH=src python examples/grad_compression_dp.py [--steps N]

Runs a tiny model replicated over an 8-way (forced CPU) data mesh and syncs
gradients with the bf16-reduce-scatter + int8-all-gather wire format with
error feedback (runtime/collectives.py).  Compares the loss trajectory with
exact fp32 sync and reports the wire-byte saving.  A final section
compresses one step's gradient tree through a device-encode ``Codec``
(``CodecConfig(encode_backend="jnp")``) -- the write path the KV pager and
checkpoint shards use -- and reports the SZ ratio plus encode dispatch
counters.

NOTE: must run as its own process (device count is locked at first jax use):
the script re-execs itself with XLA_FLAGS when needed.
"""

import argparse
import os
import sys

if "--inner" not in sys.argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " \
        + os.environ.get("XLA_FLAGS", "")
    os.execv(sys.executable,
             [sys.executable, __file__, "--inner"] + sys.argv[1:])

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import Codec, CodecConfig  # noqa: E402
from repro.core.sz.compressor import Compressed  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.runtime import collectives as C  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--steps", type=int, default=60,
                    help="optimizer steps per scheme (default 60)")
    args = ap.parse_args()

    mesh = make_host_mesh(data=8)
    n_shards = 8
    dim = 512

    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (dim,))

    def local_loss(w, x, y):
        return jnp.mean((x @ w - y) ** 2)

    sync, init_res = C.make_dp_gradient_sync(mesh, eb=1e-6)

    def data_for(shard, step):
        k = jax.random.fold_in(jax.random.PRNGKey(7 * shard + 1), step)
        x = jax.random.normal(k, (64, dim))
        y = x @ w_true + 0.01 * jax.random.normal(k, (64,))
        return x, y

    g_hist = []
    for scheme in ("exact_f32", "compressed"):
        w = jnp.zeros((dim,))
        res = init_res({"w": jnp.zeros((n_shards, dim))})
        losses = []
        for step in range(args.steps):
            gs, ls = [], []
            for s in range(n_shards):
                x, y = data_for(s, step)
                ls.append(float(local_loss(w, x, y)))
                gs.append(jax.grad(local_loss)(w, x, y))
            g_stack = jnp.stack(gs)
            if scheme == "exact_f32":
                g = g_stack.mean(0)
            else:
                out, res = sync({"w": g_stack}, res)
                g = out["w"][0]
                g_hist.append(g_stack)
            w = w - 0.05 * g
            losses.append(sum(ls) / n_shards)
        print(f"{scheme:12s}: loss {losses[0]:.4f} -> {losses[-1]:.6f}")

    n = dim
    print(f"wire bytes/param/step: fp32 all-reduce="
          f"{C.wire_bytes(n, 'allreduce_f32') / n:.1f}  "
          f"compressed={C.wire_bytes(n, 'rs_bf16_ag_int8') / n:.1f}  "
          f"({C.wire_bytes(n, 'allreduce_f32') / C.wire_bytes(n, 'rs_bf16_ag_int8'):.2f}x less traffic)")

    # --- SZ-compress the gradient history through the device encode path ---
    # The same write path the KV pager / checkpoint shards use: quantize ->
    # histogram -> bit-pack stay device-resident; only the 1024-entry
    # histogram crosses to host for codebook construction.  The per-shard
    # gradient history (steps x shards x dim) is the kind of payload an
    # in-step gradient logger would spill.
    g_last = {"w": jnp.stack(g_hist)}
    codec = Codec(CodecConfig(eb=1e-3, encode_backend="jnp"))
    codec.reset_stats()
    ctree = codec.compress_tree(g_last)
    leaves = [c for c in jax.tree_util.tree_leaves(
        ctree, is_leaf=lambda x: isinstance(x, Compressed))
        if isinstance(c, Compressed)]
    raw = sum(c.original_bytes for c in leaves)
    stored = sum(c.compressed_bytes for c in leaves)
    restored = codec.decompress_tree(ctree)
    err = max(float(jnp.max(jnp.abs(restored[k] - g_last[k])))
              for k in g_last)
    st = codec.stats
    print(f"grad tree via encode_backend='jnp': {raw} B -> {stored} B "
          f"(ratio {raw / max(stored, 1):.2f}x, max err {err:.2e}; "
          f"{st['encode_dispatches']} encode dispatches, "
          f"{st['encode_fallbacks']} fallbacks)")


if __name__ == "__main__":
    main()
