"""Quickstart: compress a scientific field, decompress it three ways.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import api
from repro.data.pipeline import smooth_field


def main():
    # A Lorenzo-predictable "simulation snapshot" (see benchmarks/datasets.py
    # for surrogates calibrated to the paper's eight datasets).
    x = smooth_field((512, 512), seed=0)
    print(f"input: {x.shape} float32, {x.nbytes / 2**20:.1f} MiB")

    c = api.compress(x, eb=1e-3, mode="rel")
    print(f"compressed: {c.compressed_bytes / 2**20:.2f} MiB "
          f"(ratio {c.ratio:.2f}x, eb {c.eb:.3e})")

    for method in ("gap", "selfsync", "naive_ref"):
        xh = np.asarray(api.decompress(c, method=method))
        err = np.abs(xh - x).max()
        print(f"decompress[{method:10s}]: max err {err:.3e} "
              f"(bound {c.eb_effective:.3e}) "
          f"{'OK' if err <= c.eb_effective else 'VIOLATION'}")

    # kernel path (Pallas, interpret mode on CPU), tuned per-CR-class tiles
    xh = np.asarray(api.decompress(c, method="gap", backend="pallas",
                                   tuned=True))
    print(f"decompress[pallas-tuned]: max err {np.abs(xh - x).max():.3e}")

    # batched multi-tensor decode: one decode-write dispatch per CR class
    # across all tensors (how checkpoint shards / KV blocks restore).
    shards = [api.compress(smooth_field((128, 512), seed=s), eb=1e-3)
              for s in range(4)]
    be = api.get_backend("ref")
    be.reset_stats()
    outs = api.decompress_batch(shards)
    print(f"decompress_batch[4 shards]: "
          f"{be.stats['decode_write_dispatches']} class dispatches, "
          f"max err {max(float(np.abs(np.asarray(o) - smooth_field((128, 512), seed=s)).max()) for s, o in enumerate(outs)):.3e}")


if __name__ == "__main__":
    main()
