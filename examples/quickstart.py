"""Quickstart: compress a scientific field, decompress it three ways.

    PYTHONPATH=src python examples/quickstart.py

All policy lives in one ``CodecConfig``: the error bound on the encode
side, the sync method / decode strategy / backend on the decode side.  A
``Codec`` is the configured session -- it also caches phase 1-3 decoder
plans by content digest, so decoding the same tensor twice only pays the
decode-write phase the second time.
"""

import numpy as np

from repro.core.api import Codec, CodecConfig
from repro.data.pipeline import smooth_field


def main():
    # A Lorenzo-predictable "simulation snapshot" (see benchmarks/datasets.py
    # for surrogates calibrated to the paper's eight datasets).
    x = smooth_field((512, 512), seed=0)
    print(f"input: {x.shape} float32, {x.nbytes / 2**20:.1f} MiB")

    codec = Codec()   # defaults: eb 1e-3 relative, gap-array, ref backend
    c = codec.compress(x)
    print(f"compressed: {c.compressed_bytes / 2**20:.2f} MiB "
          f"(ratio {c.ratio:.2f}x, eb {c.eb:.3e})")

    for method in ("gap", "selfsync", "naive_ref"):
        xh = np.asarray(Codec(CodecConfig(method=method)).decompress(c))
        err = np.abs(xh - x).max()
        print(f"decompress[{method:10s}]: max err {err:.3e} "
              f"(bound {c.eb_effective:.3e}) "
              f"{'OK' if err <= c.eb_effective else 'VIOLATION'}")

    # kernel path (Pallas, interpret mode on CPU) with the online tuner's
    # per-CR-class tiles: one config, no flag soup.
    tuned = Codec(CodecConfig(backend="pallas", strategy="tuned"))
    xh = np.asarray(tuned.decompress(c))
    print(f"decompress[pallas-tuned]: max err {np.abs(xh - x).max():.3e}")

    # batched multi-tensor decode: one decode-write dispatch per CR class
    # across all tensors (how checkpoint shards / KV blocks restore).
    shards = [codec.compress(smooth_field((128, 512), seed=s))
              for s in range(4)]
    codec.reset_stats()
    outs = codec.decompress_batch(shards)
    print(f"decompress_batch[4 shards]: "
          f"{codec.stats['decode_write_dispatches']} class dispatches, "
          f"max err {max(float(np.abs(np.asarray(o) - smooth_field((128, 512), seed=s)).max()) for s, o in enumerate(outs)):.3e}")

    # pytree round trip: Compressed leaves in, decoded arrays out.
    tree = {"layer0": {"w": smooth_field((256, 64), seed=7)},
            "step": np.int32(3)}
    back = codec.decompress_tree(codec.compress_tree(tree))
    err = np.abs(np.asarray(back["layer0"]["w"]) - tree["layer0"]["w"]).max()
    print(f"compress_tree/decompress_tree: max err {err:.3e}, "
          f"step passthrough {int(back['step'])}")


if __name__ == "__main__":
    main()
