"""End-to-end driver: train a ~100M-param qwen3-style model for a few
hundred steps with checkpointing (deliverable b's end-to-end driver).

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

~100M params: qwen3-0.6b reduced to 6 layers / d_model 512 keeps the full
substrate (data pipeline, AdamW, checkpoint/restart) on one CPU device.
"""

import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    first, last = train.main([
        "--arch", "qwen3-0.6b", "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "25",
    ])
    assert last < first, "loss did not decrease"
    print(f"loss {first:.3f} -> {last:.3f}: OK")


if __name__ == "__main__":
    main()
