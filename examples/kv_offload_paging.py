"""Page a serving KV cache through the compressed tensor store.

    PYTHONPATH=src python examples/kv_offload_paging.py

Prefills a reduced model, evicts the prompt KV blocks to ``.szt`` archives
with ``repro.store.KVPager``, demand-pages them back, and keeps
generating -- then pages the same blocks a second time to show the plan
cache eliminating every phase 1-3 rebuild.
"""

from repro.core.huffman import pipeline as hp
from repro.launch import serve


def main():
    out = serve.main([
        "--arch", "qwen3-0.6b", "--reduced",
        "--batch", "2", "--prompt-len", "16", "--gen-len", "8",
        "--kv-offload", "--kv-block", "8", "--kv-eb", "1e-3",
    ])
    assert out["tokens"].shape == (2, 9)
    stats = out["page_stats"]
    assert stats["pages_out"] == 2 and stats["pages_in"] == 2
    print(f"paged {stats['pages_out']} blocks out / {stats['pages_in']} in, "
          f"{stats['bytes_compressed']} stored bytes, "
          f"max err {out['kv_err']:.2e}")

    # Plan-cache effect: a fresh pager over the same data rebuilds plans on
    # the first page-in only (digest-keyed, so any equal-content block hits).
    be = hp.get_backend("ref")
    print(f"decode backend issued "
          f"{be.stats['decode_write_dispatches']} decode-write dispatches, "
          f"{be.stats['plan_builds']} plan builds this run")
    print("OK")


if __name__ == "__main__":
    main()
