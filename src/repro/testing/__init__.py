"""Testing utilities: deterministic fault injection (see ``faults``).

Importable by tests AND by ``tools/faultinject.py``; keep it dependency-
light (numpy + the repro package itself).
"""

from repro.testing.faults import (  # noqa: F401
    CampaignReport,
    CaseResult,
    FaultCase,
    NAMED_ERRORS,
    build_corpus,
    flip_bit,
    run_campaign,
    truncate_file,
)
