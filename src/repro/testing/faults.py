"""Deterministic fault-injection campaigns over every decode consumer.

The robustness layer's invariant is falsifiable: **every injected
corruption is either detected or safely contained -- never silent wrong
data, never a hang, never an unnamed crash.**  This module injects seeded
faults -- bit-flips in archive bytes and in-memory ``Compressed`` fields,
truncations, torn manifests, missing files, transient IO errors -- into
the four consumers (direct decode, store archives, checkpoint restore,
KV paging) and classifies each outcome:

  detected    a named error (``StoreError`` family incl. ``PageLostError``
              and ``StoreIOError``, ``CheckpointIntegrityError``,
              ``DecodeGuardError``) reached the caller
  bit_exact   the fault landed in dead bytes (alignment padding, unused
              header fields); output is bit-identical to the baseline
  recovered   a non-raise recovery policy salvaged the read: intact
              entries bit-exact, failed ones quarantined / zero-filled /
              retried -- and the degradation was *reported* (quarantine
              dict, ``pages_lost`` / ``io_retries`` counters)
  contained   an un-checksummed in-memory corruption decoded to garbage,
              but bounded: right shape/dtype, terminated, no crash

  silent      VIOLATION -- wrong data with no error and no report
  unnamed     VIOLATION -- an exception outside the named families
  hang        VIOLATION -- the case exceeded its watchdog timeout

``run_campaign(seed=..., cases=...)`` is pure-deterministic per seed (the
case schedule round-robins over the fault channels); ``tools/
faultinject.py`` is the CLI wrapper CI runs on every PR.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import threading

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointIntegrityError, \
    CheckpointManager
from repro.core.cache import PlanCache
from repro.core.codec import Codec, CodecConfig
from repro.core.huffman import pipeline as hp
from repro.core.sz import compressor as sz
from repro.store import Archive, ArchiveWriter, KVPager
from repro.store import format as F

#: Exception families a consumer may legitimately raise on corrupt input.
#: Anything else escaping a consumer is an "unnamed" invariant violation.
NAMED_ERRORS = (F.StoreError, CheckpointIntegrityError, hp.DecodeGuardError)

VIOLATIONS = ("silent", "unnamed", "hang")


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def flip_bit(path: str, byte_offset: int, bit: int = 0) -> None:
    """Flip one bit of a file in place."""
    with open(path, "r+b") as f:
        f.seek(byte_offset)
        b = f.read(1)
        f.seek(byte_offset)
        f.write(bytes([b[0] ^ (1 << bit)]))


def truncate_file(path: str, size: int) -> None:
    with open(path, "r+b") as f:
        f.truncate(size)


def flip_array_bit(arr: np.ndarray, rng) -> np.ndarray:
    """Copy ``arr`` with one random bit flipped in its raw bytes."""
    raw = bytearray(np.ascontiguousarray(arr).tobytes())
    if not raw:
        return np.array(arr)
    i = int(rng.randint(len(raw)))
    raw[i] ^= 1 << int(rng.randint(8))
    return np.frombuffer(bytes(raw), dtype=arr.dtype).reshape(arr.shape)


# ---------------------------------------------------------------------------
# Case / report records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultCase:
    consumer: str            # "store" | "decode" | "checkpoint" | "paging"
    kind: str                # e.g. "flip", "truncate", "torn_manifest"
    seed: int
    detail: str = ""


@dataclasses.dataclass
class CaseResult:
    case: FaultCase
    outcome: str             # see module docstring
    note: str = ""

    @property
    def ok(self) -> bool:
        return self.outcome not in VIOLATIONS


@dataclasses.dataclass
class CampaignReport:
    results: list

    @property
    def violations(self) -> list:
        return [r for r in self.results if not r.ok]

    @property
    def ok(self) -> bool:
        return not self.violations

    def counts(self) -> dict:
        out: dict = {}
        for r in self.results:
            key = (r.case.consumer, r.outcome)
            out[key] = out.get(key, 0) + 1
        return out

    def summary(self) -> str:
        consumers = sorted({r.case.consumer for r in self.results})
        outcomes = ["detected", "bit_exact", "recovered", "contained",
                    "silent", "unnamed", "hang"]
        counts = self.counts()
        width = max(len(c) for c in consumers + ["consumer"]) + 2
        lines = ["consumer".ljust(width)
                 + "".join(o.rjust(11) for o in outcomes)]
        for c in consumers:
            lines.append(c.ljust(width) + "".join(
                str(counts.get((c, o), 0)).rjust(11) for o in outcomes))
        lines.append(f"total {len(self.results)} cases, "
                     f"{len(self.violations)} violations")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Corpus: one small world every case perturbs a copy of
# ---------------------------------------------------------------------------


def _smooth(rng, n: int) -> np.ndarray:
    return np.cumsum(rng.randn(n).astype(np.float32) * 0.02) \
        .astype(np.float32)


@dataclasses.dataclass
class Corpus:
    dir: str
    codec: Codec
    arrays: dict             # name -> np.float32 baseline
    archive: str             # pristine .szt path
    baseline: dict           # name -> decoded np baseline (bit-level truth)
    ckpt_dir: str            # pristine checkpoint dir (2 steps)
    ckpt_baseline: dict      # fname -> np array from a clean restore
    kv_dir: str              # pager directory
    kv_meta: dict            # block meta of the offloaded block
    kv_block_id: int
    kv_block_bytes: bytes    # pristine block archive bytes
    kv_cache: dict           # post-offload cache template (span zeroed)
    kv_snapshot: dict        # name -> pre-offload np.float32 values


def build_corpus(base_dir: str, backend: str = "ref",
                 seed: int = 1234) -> Corpus:
    """Build the pristine world: archive + checkpoint + offloaded KV block.

    Small on purpose (CI runs 200 cases against it); every decode shape
    repeats across cases so jit compilations amortize.
    """
    rng = np.random.RandomState(seed)
    codec = Codec(CodecConfig(backend=backend), plan_cache=PlanCache())
    os.makedirs(base_dir, exist_ok=True)

    # -- store archive ------------------------------------------------------
    arrays = {f"t{i}": _smooth(rng, n)
              for i, n in enumerate((4096, 4096, 2048))}
    archive = os.path.join(base_dir, "corpus.szt")
    with ArchiveWriter(archive, codec=codec) as w:
        for name, arr in arrays.items():
            w.add_array(name, arr)
    with Archive(archive, codec=codec) as ar:
        baseline = {k: np.asarray(v)
                    for k, v in ar.read_all(group_chunks=2).items()}

    # -- checkpoint (2 steps so a torn newest manifest can fall back) -------
    ckpt_dir = os.path.join(base_dir, "ckpt")
    mgr = CheckpointManager(ckpt_dir, codec=codec, compress_min_size=1024)
    params = {"w1": rng.randn(48, 48).astype(np.float32),
              "w2": rng.randn(40, 40).astype(np.float32),
              "count": np.int32(3)}
    mgr.save(1, params)
    mgr.save(2, params)
    r = mgr.restore(2)
    ckpt_baseline = {f"params.{k}": np.asarray(v)
                     for k, v in r["params"].items()}

    # -- one offloaded KV block ---------------------------------------------
    kv_dir = os.path.join(base_dir, "kv")
    pager = KVPager(kv_dir, codec=codec, seq_axis=2)
    cache = {"k": jnp.asarray(rng.randn(1, 1, 16, 8).astype(np.float32)),
             "v": jnp.asarray(rng.randn(1, 1, 16, 8).astype(np.float32))}
    kv_snapshot = {k: np.asarray(v, np.float32) for k, v in cache.items()}
    cache, block_id = pager.offload(cache, 0, 8, keys=["k", "v"])
    meta = pager.block_meta(block_id)
    with open(meta["path"], "rb") as f:
        kv_block_bytes = f.read()

    return Corpus(dir=base_dir, codec=codec, arrays=arrays, archive=archive,
                  baseline=baseline, ckpt_dir=ckpt_dir,
                  ckpt_baseline=ckpt_baseline, kv_dir=kv_dir,
                  kv_meta=meta, kv_block_id=block_id,
                  kv_block_bytes=kv_block_bytes, kv_cache=dict(cache),
                  kv_snapshot=kv_snapshot)


# ---------------------------------------------------------------------------
# Fault channels (one function per channel; all deterministic per rng)
# ---------------------------------------------------------------------------


def _bit_exact(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


def _work_archive(corpus: Corpus, mutate) -> str:
    work = os.path.join(corpus.dir, "work.szt")
    shutil.copyfile(corpus.archive, work)
    mutate(work)
    return work


def _read_and_classify(corpus: Corpus, work: str) -> CaseResult | str:
    """Open + fully decode a (possibly corrupt) archive under "raise"."""
    with Archive(work, codec=corpus.codec) as ar:
        outs = ar.read_all(group_chunks=2, policy="raise")
    for name, arr in outs.items():
        if not _bit_exact(arr, corpus.baseline[name]):
            return ("silent", f"{name} decoded to different bytes "
                              f"with no error")
    if set(outs) != set(corpus.baseline):
        return ("silent", "chunks vanished without an error")
    return ("bit_exact", "")


def case_store_flip(corpus: Corpus, rng) -> tuple:
    size = os.path.getsize(corpus.archive)
    off, bit = int(rng.randint(size)), int(rng.randint(8))
    work = _work_archive(corpus, lambda p: flip_bit(p, off, bit))
    return _read_and_classify(corpus, work)


def case_store_truncate(corpus: Corpus, rng) -> tuple:
    size = os.path.getsize(corpus.archive)
    cut = int(rng.randint(size))          # [0, size): always drops bytes
    work = _work_archive(corpus, lambda p: truncate_file(p, cut))
    return _read_and_classify(corpus, work)


def case_store_policy(corpus: Corpus, rng) -> tuple:
    """Corrupt one chunk's payload; skip/zero_fill must salvage the rest."""
    name = list(corpus.arrays)[int(rng.randint(len(corpus.arrays)))]
    with Archive(corpus.archive, codec=corpus.codec) as ar:
        rec = ar.chunk(name)
        off = rec.units.offset + int(rng.randint(max(rec.units.length, 1)))
    work = _work_archive(
        corpus, lambda p: flip_bit(p, off, int(rng.randint(8))))
    policy = ("skip", "zero_fill")[int(rng.randint(2))]
    failures: list = []
    with Archive(work, codec=corpus.codec) as ar:
        outs = ar.read_all(group_chunks=2, policy=policy,
                           on_error=lambda n, e: failures.append((n, e)))
        stats = dict(ar.stats)
    if not failures:
        # units bytes are fully CRC-covered, so a flip inside the blob
        # extent must fail -- reaching here without a failure means the
        # decode silently absorbed corruption.
        if all(k in outs and _bit_exact(outs[k], corpus.baseline[k])
               for k in corpus.baseline):
            return ("bit_exact", "flip landed in dead bytes")
        return ("silent", "corruption absorbed without a failure report")
    if not all(isinstance(e, NAMED_ERRORS) for _, e in failures):
        return ("unnamed", f"on_error saw {failures}")
    for k, arr in outs.items():
        if k == name and policy == "zero_fill":
            if np.any(np.asarray(arr)):
                return ("silent", f"zero_fill of {k} is not zero")
        elif not _bit_exact(arr, corpus.baseline[k]):
            return ("silent", f"intact chunk {k} changed under {policy}")
    degraded = stats["chunks_skipped"] + stats["chunks_zero_filled"]
    if policy == "skip" and name not in outs and degraded:
        return ("recovered", f"{name} skipped, rest intact")
    if policy == "zero_fill" and name in outs and degraded:
        return ("recovered", f"{name} zero-filled, rest intact")
    return ("silent", "degradation was not reported")


def case_decode_field_flip(corpus: Corpus, rng) -> tuple:
    """Flip a bit in an in-memory ``Compressed`` field; no checksum guards
    this channel, so garbage output is acceptable -- crash/hang is not."""
    name = list(corpus.arrays)[int(rng.randint(len(corpus.arrays)))]
    codec = Codec(corpus.codec.config, plan_cache=PlanCache())
    c = codec.compress(jnp.asarray(corpus.arrays[name]))
    field = ("units", "gaps", "outlier_pos", "outlier_val",
             "total_bits", "dec_len", "enc_len")[int(rng.randint(7))]
    stream = c.stream
    book = c.codebook
    if field in ("units", "gaps"):
        flipped = jnp.asarray(flip_array_bit(
            np.asarray(getattr(stream, field)), rng))
        stream = dataclasses.replace(stream, **{field: flipped})
    elif field == "total_bits":
        delta = int(rng.randint(1, 1 << 20))
        stream = dataclasses.replace(
            stream, total_bits=jnp.asarray(
                int(stream.total_bits) + delta, jnp.int32))
    elif field in ("outlier_pos", "outlier_val"):
        flipped = jnp.asarray(flip_array_bit(np.asarray(getattr(c, field)),
                                             rng))
        c = dataclasses.replace(c, **{field: flipped})
    else:                                 # dec_len / enc_len table entry
        tab = np.array(getattr(book, field))
        if tab.size:
            tab[int(rng.randint(tab.size))] = 200   # >> max_len
        book = dataclasses.replace(book, **{field: tab})
    c = dataclasses.replace(c, stream=stream, codebook=book)
    c.__dict__.pop("_digest", None)       # never reuse the pristine plan

    out = codec.decompress(c)
    out_np = np.asarray(out)              # forces device completion
    if out_np.shape != tuple(c.shape):
        return ("silent", f"shape {out_np.shape} != {tuple(c.shape)}")
    if not np.isfinite(out_np).all():
        # quantized reconstruction is bounded by construction; NaN/inf can
        # only come from reading memory it shouldn't
        return ("silent", "non-finite values decoded")
    if _bit_exact(out_np, corpus.baseline[name]):
        return ("bit_exact", f"{field} flip was inert")
    return ("contained", f"{field} corrupt -> bounded garbage")


_CKPT_POLICIES = ("raise", "skip", "zero_fill")


def case_checkpoint(corpus: Corpus, rng) -> tuple:
    """Corrupt a copied checkpoint dir; restore under a cycling policy."""
    work = os.path.join(corpus.dir, "ckpt_work")
    shutil.rmtree(work, ignore_errors=True)
    shutil.copytree(corpus.ckpt_dir, work)
    step2 = os.path.join(work, "step_00000002")
    targets = [os.path.join(step2, "archive.szt"),
               os.path.join(step2, "manifest.json"),
               os.path.join(step2, "params.count.npy")]
    kind = int(rng.randint(4))
    if kind < 2:                          # flip a byte somewhere
        path = targets[int(rng.randint(len(targets)))]
        flip_bit(path, int(rng.randint(os.path.getsize(path))),
                 int(rng.randint(8)))
    elif kind == 2:                       # torn file (truncation)
        path = targets[int(rng.randint(len(targets)))]
        truncate_file(path, int(rng.randint(os.path.getsize(path))))
    else:                                 # missing file
        os.unlink(targets[int(rng.randint(len(targets)))])

    policy = _CKPT_POLICIES[int(rng.randint(3))]
    mgr = CheckpointManager(work, codec=corpus.codec,
                            compress_min_size=1024)
    try:
        r = mgr.restore(policy=policy)
    except NAMED_ERRORS + (CheckpointIntegrityError,) as e:
        if policy == "raise":
            return ("detected", type(e).__name__)
        return ("unnamed", f"{policy} still raised {type(e).__name__}: {e}")
    if r is None:
        return ("recovered", "no intact step (all quarantined)")
    quarantined = set(r.get("quarantined", ()))
    fallback = r.get("fallback_from", [])
    flat = {f"params.{k}": v for k, v in r["params"].items()}
    for fname, want in corpus.ckpt_baseline.items():
        got = flat.get(fname)
        if got is None:
            # A manifest bit-flip can mangle the *name* an entry is
            # reported under; any non-empty quarantine/fallback report
            # still satisfies "never silent".
            if policy != "raise" and (quarantined or fallback):
                continue
            return ("silent", f"{fname} vanished unreported")
        if fname in quarantined:
            if policy == "zero_fill" and np.any(np.asarray(got)):
                return ("silent", f"zero_fill of {fname} is not zero")
            continue
        if not _bit_exact(got, want):
            return ("silent", f"{fname} changed, not quarantined")
    if quarantined or fallback:
        return ("recovered", f"quarantined={sorted(quarantined)} "
                             f"fallback={len(fallback)}")
    return ("bit_exact", "fault landed in dead bytes")


def case_checkpoint_torn_save(corpus: Corpus, rng) -> tuple:
    """Simulate a crash mid-save: a stray .tmp step dir + torn newest
    manifest.  Salvage must land on the newest intact step."""
    work = os.path.join(corpus.dir, "ckpt_work")
    shutil.rmtree(work, ignore_errors=True)
    shutil.copytree(corpus.ckpt_dir, work)
    # half-renamed save attempt
    shutil.copytree(os.path.join(work, "step_00000002"),
                    os.path.join(work, "step_00000003.tmp"))
    mpath = os.path.join(work, "step_00000002", "manifest.json")
    truncate_file(mpath, int(rng.randint(os.path.getsize(mpath))))
    mgr = CheckpointManager(work, codec=corpus.codec,
                            compress_min_size=1024)
    try:
        mgr.restore(policy="raise")
        # a torn manifest that truncation left as valid JSON would have to
        # be byte-identical up to the cut -- truncating strictly inside a
        # json.dump output always breaks it, so reaching here means the
        # cut was at full size (rng hit size-1 edge) -- treat as detected
        # only if values match baseline.
    except CheckpointIntegrityError:
        pass
    except Exception as e:                # noqa: BLE001
        return ("unnamed", f"{type(e).__name__}: {e}")
    r = mgr.restore(policy="skip")
    if r is None or r["step"] != 1:
        return ("silent", f"fell back to {r and r['step']}, expected 1")
    flat = {f"params.{k}": v for k, v in r["params"].items()}
    for fname, want in corpus.ckpt_baseline.items():
        if not _bit_exact(flat.get(fname), want):
            return ("silent", f"{fname} wrong after fallback")
    if not r["fallback_from"]:
        return ("silent", "fallback not reported")
    return ("recovered", "fell back to step 1")


def case_paging(corpus: Corpus, rng) -> tuple:
    """Corrupt / remove the offloaded block; page_in must raise the named
    PageLostError (+ counter) or restore bit-exact values."""
    path = corpus.kv_meta["path"]
    with open(path, "wb") as f:
        f.write(corpus.kv_block_bytes)    # restore pristine block
    kind = int(rng.randint(3))
    if kind == 0:
        flip_bit(path, int(rng.randint(len(corpus.kv_block_bytes))),
                 int(rng.randint(8)))
    elif kind == 1:
        truncate_file(path, int(rng.randint(len(corpus.kv_block_bytes))))
    else:
        os.unlink(path)
    pager = KVPager(corpus.kv_dir, codec=corpus.codec, seq_axis=2)
    pager.adopt_block(corpus.kv_block_id, corpus.kv_meta)
    cache = dict(corpus.kv_cache)
    try:
        cache = pager.page_in(cache, corpus.kv_block_id)
    except F.StoreError as e:
        from repro.store import PageLostError
        if not isinstance(e, PageLostError):
            return ("unnamed", f"expected PageLostError, got "
                               f"{type(e).__name__}")
        if pager.stats["pages_lost"] != 1:
            return ("silent", "pages_lost counter not incremented")
        if corpus.kv_block_id in pager._blocks:
            return ("silent", "lost block not evicted")
        return ("detected", "PageLostError + eviction + counter")
    lo, hi = corpus.kv_meta["lo"], corpus.kv_meta["hi"]
    for k in corpus.kv_meta["names"]:
        got = np.asarray(cache[k][:, :, lo:hi], np.float32)
        want = np.asarray(corpus.kv_snapshot[k][:, :, lo:hi], np.float32)
        # paging is lossy by design: compare against a pristine page-in
        # is bit-exact only because the block bytes are identical
        if got.tobytes() != want.tobytes():
            # the baseline snapshot is pre-compression; recompute the
            # legitimate decode from pristine bytes instead
            with open(path, "wb") as f:
                f.write(corpus.kv_block_bytes)
            ref_pager = KVPager(corpus.kv_dir, codec=corpus.codec,
                                seq_axis=2)
            ref_pager.adopt_block(corpus.kv_block_id, corpus.kv_meta)
            ref = ref_pager.page_in(dict(corpus.kv_cache),
                                    corpus.kv_block_id)
            if got.tobytes() != np.asarray(ref[k][:, :, lo:hi],
                                           np.float32).tobytes():
                return ("silent", f"block {k} decoded differently "
                                  f"with no error")
    return ("bit_exact", "fault landed in dead bytes")


def inject_blob_failures(ar: Archive, n: int) -> dict:
    """Make the next ``n`` raw blob reads of ``ar`` raise ``OSError``
    (transient-IO simulation).  Returns the shared counter state."""
    orig = ar._blob
    state = {"left": n, "injected": 0}

    def flaky(ref, dtype):
        if state["left"] > 0:
            state["left"] -= 1
            state["injected"] += 1
            raise OSError("injected transient IO failure")
        return orig(ref, dtype)

    ar._blob = flaky
    return state


def case_io_transient(corpus: Corpus, rng) -> tuple:
    """Transient OSErrors during chunk reads: few must be retried away
    (bit-exact + io_retries counted); a persistent failure must surface
    as the named StoreIOError."""
    persistent = bool(rng.randint(2))
    n = 1000 if persistent else 1 + int(rng.randint(2))
    with Archive(corpus.archive, codec=corpus.codec) as ar:
        state = inject_blob_failures(ar, n)
        try:
            outs = ar.read_all(group_chunks=2, policy="raise")
        except F.StoreIOError:
            if not persistent:
                return ("unnamed", "transient failure was not retried")
            return ("detected", "persistent IO -> StoreIOError")
        if persistent:
            return ("silent", "persistent IO error vanished")
        if ar.stats["io_retries"] < 1 or state["injected"] < n:
            return ("silent", "retry not recorded")
        for k, arr in outs.items():
            if not _bit_exact(arr, corpus.baseline[k]):
                return ("silent", f"{k} wrong after retry")
        return ("recovered", f"{state['injected']} transient errors "
                             f"retried away")


CHANNELS = (case_store_flip, case_store_truncate, case_store_policy,
            case_decode_field_flip, case_checkpoint,
            case_checkpoint_torn_save, case_paging, case_io_transient)

_CONSUMER = {case_store_flip: "store", case_store_truncate: "store",
             case_store_policy: "store",
             case_decode_field_flip: "decode",
             case_checkpoint: "checkpoint",
             case_checkpoint_torn_save: "checkpoint",
             case_paging: "paging", case_io_transient: "store"}


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def _run_with_watchdog(fn, timeout: float):
    """Run ``fn`` on a watchdog thread; a case that outlives ``timeout``
    is a hang (the daemon thread is abandoned -- acceptable for a test
    harness, fatal evidence for the decoder)."""
    box: dict = {}

    def target():
        try:
            box["value"] = fn()
        except BaseException as e:        # noqa: BLE001 -- classified below
            box["exc"] = e

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout)
    if t.is_alive():
        return "hang", None
    if "exc" in box:
        return "exc", box["exc"]
    return "ok", box["value"]


def run_case(channel, corpus: Corpus, seed: int,
             timeout: float = 120.0) -> CaseResult:
    rng = np.random.RandomState(seed)
    case = FaultCase(consumer=_CONSUMER[channel],
                     kind=channel.__name__.split("case_", 1)[-1], seed=seed)
    status, value = _run_with_watchdog(lambda: channel(corpus, rng), timeout)
    if status == "hang":
        return CaseResult(case, "hang", f"exceeded {timeout}s watchdog")
    if status == "exc":
        if isinstance(value, NAMED_ERRORS):
            return CaseResult(case, "detected", type(value).__name__)
        return CaseResult(case, "unnamed",
                          f"{type(value).__name__}: {value}")
    outcome, note = value
    return CaseResult(case, outcome, note)


def run_campaign(seed: int = 0, cases: int = 200,
                 base_dir: "str | None" = None, backend: str = "ref",
                 timeout: float = 120.0, progress=None) -> CampaignReport:
    """Run a seeded campaign; deterministic case schedule per seed.

    ``progress(i, result)`` is called after each case (the CLI uses it).
    The corpus lives in ``base_dir`` (a fresh temp dir by default).
    """
    import tempfile

    cleanup = base_dir is None
    base_dir = base_dir or tempfile.mkdtemp(prefix="faultinject_")
    corpus = build_corpus(base_dir, backend=backend)
    rng = np.random.RandomState(seed)
    results = []
    try:
        for i in range(cases):
            channel = CHANNELS[i % len(CHANNELS)]
            result = run_case(channel, corpus,
                              int(rng.randint(0, 2 ** 31 - 1)),
                              timeout=timeout)
            results.append(result)
            if progress is not None:
                progress(i, result)
    finally:
        if cleanup:
            shutil.rmtree(base_dir, ignore_errors=True)
    return CampaignReport(results)
