"""Deterministic, sharded, skip-ahead synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) via counter-based PRNG
folding -- this is what makes restart-exactly-where-you-died and
straggler skip-ahead work (runtime/fault_tolerance.py): any host can
reconstruct any other host's batch without coordination.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard_id: int = 0
    mode: str = "zipf"        # "zipf" (realistic marginals) | "uniform"

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class SyntheticLM:
    """Deterministic synthetic next-token data."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = jax.random.PRNGKey(cfg.seed)
        self._key = jax.random.fold_in(base, cfg.shard_id)
        if cfg.mode == "zipf":
            ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
            p = 1.0 / ranks ** 1.1
            self._logits = jnp.asarray(np.log(p / p.sum()), jnp.float32)
        else:
            self._logits = jnp.zeros((cfg.vocab,), jnp.float32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        k = jax.random.fold_in(self._key, step)
        tokens = jax.random.categorical(
            k, self._logits, shape=(cfg.shard_batch, cfg.seq_len))
        tokens = tokens.astype(jnp.int32)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((cfg.shard_batch, 1), -1, jnp.int32)],
            axis=1)
        return {"tokens": tokens, "labels": labels}

    def prefetch(self, start_step: int, depth: int = 2):
        """Background-thread prefetch iterator (host-side double buffering)."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch_at(step)))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def smooth_field(shape, seed: int = 0, dtype=np.float32):
    """Synthetic 'scientific' field: integrated noise -> Lorenzo-predictable.

    Used by benchmarks to emulate HPC datasets at controlled compressibility
    (see benchmarks/datasets.py)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float64)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax)
    x /= np.abs(x).max() + 1e-9
    return x.astype(dtype)
