"""Trip-count-aware cost analysis over partitioned HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE -- a while
loop body (our scan-over-layers, microbatch scan, attention/CE chunk scans)
contributes a single iteration, under-counting FLOPs/bytes by the trip count
(~n_layers x).  This walker rebuilds per-computation costs bottom-up and
multiplies while bodies by their trip count (parsed from the loop condition's
``compare(counter, constant(N)), direction=LT``).

Costs modelled:
  * FLOPs: dot ops -- 2 * prod(result_shape) * prod(lhs contracting dims)
    (fusion-internal dots included);
  * bytes: result + operand bytes of real ops (parameters / GTEs / bitcasts /
    tuples excluded; fusions counted at the fusion boundary, which matches
    "HBM traffic" on a machine that keeps fusion temporaries on-chip);
  * collectives: operand bytes per op type (same convention as dryrun).

This is an HBM/FLOP *model*, not a measurement; EXPERIMENTS.md §Roofline
cross-checks it against analytic 6ND model FLOPs per cell.
"""

from __future__ import annotations

import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE = re.compile(r"(pred|[suf]\d+|bf16|c64)\[([\d,]*)\]")
# "%name = TYPE opcode(" -- TYPE may be a tuple containing spaces; the
# opcode is the first lowercase word directly followed by "(".
_OP = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][a-z\-]*)\(")
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"(?:true_computation|false_computation|branch_computations=\{[^}]*)=?%?([\w\.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dims_list(type_str: str):
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry = None
        cur = None
        for line in hlo_text.splitlines():
            stripped = line.rstrip()
            is_hdr = (stripped.endswith("{") and ") -> " in stripped
                      and (stripped.startswith("%")
                           or stripped.startswith("ENTRY")))
            if is_hdr:
                tok = stripped.split(" ")
                name = (tok[1] if stripped.startswith("ENTRY")
                        else tok[0]).lstrip("%")
                if stripped.startswith("ENTRY"):
                    self.entry = name
                cur = name
                self.comps[cur] = []
            elif cur is not None and "=" in line:
                self.comps[cur].append(line)
        # symbol table: (comp, op name) -> result type string.  Needed
        # because compiled.as_text() omits operand types inline.
        self.types: dict[str, dict[str, str]] = {}
        for comp, lines in self.comps.items():
            tab = {}
            for line in lines:
                m = _OP.match(line)
                if m:
                    tab[m.group(1)] = m.group(2)
            self.types[comp] = tab
        self._memo: dict[str, tuple] = {}

    # -- trip counts ---------------------------------------------------------

    def trip_count(self, cond_comp: str) -> int:
        """Loop trip count: the s32[] constant compared against the counter.

        The compare may be wrapped in a fusion, so when no raw compare line
        exists we take the max s32 constant in the condition computation
        (conditions of lowered scans contain exactly the bound)."""
        lines = self.comps.get(cond_comp, [])
        consts = {}
        for l in lines:
            m = re.search(r"%?([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)", l)
            if m:
                consts[m.group(1)] = int(m.group(2))
        for l in lines:
            if "compare(" in l and "direction=LT" in l:
                for name, val in consts.items():
                    if name in l:
                        return val
        return max(consts.values()) if consts else 1

    # -- per-computation cost -------------------------------------------------

    def comp_cost(self, comp: str):
        """Returns (flops, bytes, coll_bytes) of one execution of ``comp``."""
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0, 0, 0)  # cycle guard
        flops = byts = coll = 0
        for line in self.comps.get(comp, []):
            m = _OP.match(line)
            if not m:
                continue
            _name, rtype, opcode = m.groups()
            operand_str = line[m.end() - 1:]
            if opcode in _FREE_OPS:
                continue
            if opcode == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = _COND.search(line)
                if mb and mc:
                    trips = self.trip_count(mc.group(1))
                    f, b, c = self.comp_cost(mb.group(1))
                    fc, bc, cc = self.comp_cost(mc.group(1))
                    flops += trips * (f + fc)
                    byts += trips * (b + bc)
                    coll += trips * (c + cc)
                continue
            if opcode == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation)=%?([\w\.\-]+)",
                    line)
                if "branch_computations" in line:
                    seg = line.split("branch_computations={", 1)[1]
                    seg = seg.split("}", 1)[0]
                    branches += [b.strip().lstrip("%") for b in seg.split(",")]
                costs = [self.comp_cost(b) for b in branches
                         if b in self.comps]
                if costs:
                    f = max(c[0] for c in costs)
                    b = max(c[1] for c in costs)
                    c_ = max(c[2] for c in costs)
                    flops += f
                    byts += b
                    coll += c_
                continue
            ob = self._operand_bytes(comp, operand_str)
            if opcode in ("fusion", "call"):
                mcal = _CALLS.search(line)
                if mcal and mcal.group(1) in self.comps:
                    f, b, c = self.comp_cost(mcal.group(1))
                    flops += f          # fusion-internal dots still count
                    coll += c
                # fusion boundary bytes: result + operands, minus in-place
                # aliasing: a fusion that passes a large operand through to
                # an identically-shaped result (scan-stack dynamic-update-
                # slice) touches only the updated slice, not the buffer.
                rb = _shape_bytes(rtype)
                o_types = self._operand_types(comp, operand_str)
                aliased = next((t for t in o_types
                                if t and rb > 0 and _shape_bytes(t) == rb),
                               None)
                is_dus = bool(mcal) and "dynamic-update-slice" in "".join(
                    self.comps.get(mcal.group(1), []) if mcal else [])
                if aliased is not None and is_dus:
                    others = sum(_shape_bytes(t) for t in o_types
                                 if t is not aliased)
                    byts += 2 * others  # slice read+write ~ other operands
                else:
                    byts += rb + ob
                continue
            if opcode in _COLLECTIVES:
                coll += ob
                byts += _shape_bytes(rtype) + ob
                continue
            if opcode == "dot":
                flops += self._dot_flops(comp, line, rtype, operand_str)
            byts += _shape_bytes(rtype) + ob
        self._memo[comp] = (flops, byts, coll)
        return self._memo[comp]

    def _operand_types(self, comp: str, operand_str: str):
        # operands are the %names inside the call parens (first level)
        paren = operand_str.split(")", 1)[0] if ")" in operand_str \
            else operand_str
        names = re.findall(r"%([\w\.\-]+)", paren)
        tab = self.types.get(comp, {})
        return [tab.get(n, "") for n in names]

    def _operand_bytes(self, comp: str, operand_str: str) -> int:
        inline = _shape_bytes(operand_str.split(")", 1)[0])
        if inline:
            return inline               # dump-style text with inline types
        return sum(_shape_bytes(t) for t in self._operand_types(
            comp, operand_str))

    def _dot_flops(self, comp: str, line: str, rtype: str,
                   operand_str: str) -> int:
        out_elems = 1
        for d in _dims_list(rtype):
            out_elems *= d
        mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        lhs_type = ""
        mlhs = re.search(r"dot\((\(?[^,)]*?\[[\d,]*\][^,)]*)", line)
        if mlhs:                         # dump-style inline type
            lhs_type = mlhs.group(1)
        else:
            ts = self._operand_types(comp, operand_str)
            lhs_type = ts[0] if ts else ""
        lhs_dims = _dims_list(lhs_type)
        if not (mcd and lhs_dims):
            return 2 * out_elems
        contract = 1
        for idx in mcd.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
        return 2 * out_elems * contract

    # -- public ----------------------------------------------------------------

    def totals(self):
        f, b, c = self.comp_cost(self.entry)
        return {"flops": float(f), "bytes": float(b),
                "collective_bytes": float(c)}


def corrected_costs(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).totals()
