"""Serving driver: batched autoregressive decode with optional compressed
KV-cache offload (the paper's in-memory compression use case made live).

Flow: prompt prefill (decode steps over the prompt) -> optionally compress
the prompt-phase cache with the SZ pipeline and restore it through the
optimized parallel Huffman decoder -> continue decoding.  Reports tokens/s,
cache compression ratio, and the decode-path error introduced.

``--kv-offload`` instead pages the prompt KV blocks *through the
compressed tensor store*: prefix blocks are evicted to ``.szt`` archives
(``repro.store.KVPager``) and demand-paged back before generation; repeat
page-ins of a block hit the plan cache, so steady-state paging is pure
phase-4 decode.  Page-in decodes all blocks in one class-merged dispatch
set; with ``--concurrency N`` the blocks are instead requested by N
concurrent decode streams through one shared ``repro.serving``
scheduler -- their requests coalesce within ``--batch-window`` and the
shared prefix decodes exactly once.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32 --compress-kv --kv-eb 1e-3
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --kv-offload --kv-block 16 --kv-offload-dir /tmp/kv_blocks
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --kv-offload --concurrency 8 --batch-window 0.002
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode as D
from repro.models import kvcache
from repro.models import steps as S
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-len", type=int, default=None)
    ap.add_argument("--compress-kv", action="store_true")
    ap.add_argument("--kv-eb", type=float, default=None,
                    help="relative error bound for KV compression "
                         "(default: the CodecConfig default)")
    ap.add_argument("--kv-backend", default=None,
                    help="decode backend for KV restore ('ref', 'pallas'; "
                         "default: the CodecConfig default)")
    ap.add_argument("--kv-encode-backend", default=None,
                    help="encode backend for KV eviction/compression "
                         "('ref' host path, 'jnp'/'pallas' device write "
                         "path; default: the CodecConfig default)")
    ap.add_argument("--kv-offload", action="store_true",
                    help="page prompt KV blocks out to store archives and "
                         "demand-page them back before generation")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="tokens per offloaded KV block")
    ap.add_argument("--kv-offload-dir", default=None,
                    help="directory for KV block archives "
                         "(default: a temp dir)")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="with --kv-offload: number of concurrent decode "
                         "streams paging the prompt blocks back through one "
                         "shared serving scheduler (they share the hot "
                         "prefix, so its blocks decode once; 1 = direct "
                         "batched page-in, no scheduler)")
    ap.add_argument("--batch-window", type=float, default=0.002,
                    help="scheduler batching window in seconds: page-in "
                         "requests arriving within one window coalesce "
                         "into one class-merged decode dispatch set")
    ap.add_argument("--kv-recovery", default="raise",
                    choices=["raise", "skip", "zero_fill"],
                    help="recovery policy for lost/corrupt KV blocks: "
                         "'raise' aborts on PageLostError; 'skip'/"
                         "'zero_fill' keep serving degraded -- the lost "
                         "block's span stays zeroed and "
                         "stats['pages_lost'] counts it")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    kv_len = args.kv_len or (args.prompt_len + args.gen_len)

    # One configured codec drives every KV compression path of this run
    # (offload paging AND in-memory compress/restore): eb, bound mode,
    # decode method/backend, and the plan cache travel together.
    from repro.core import Codec, CodecConfig
    overrides = {k: v for k, v in (("eb", args.kv_eb),
                                   ("backend", args.kv_backend),
                                   ("encode_backend",
                                    args.kv_encode_backend),
                                   ("recovery", args.kv_recovery))
                 if v is not None}
    kv_codec = Codec(CodecConfig(**overrides))

    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    serve = jax.jit(S.make_serve_step(cfg), static_argnums=())
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)

    cache = D.init_cache(cfg, args.batch, kv_len)
    if cfg.family == "encdec":
        # cross-attention K/V from the (stubbed) encoder features
        enc = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model), cfg.cdt)
        from repro.models import attention as A
        import jax.numpy as _j
        xk = []
        xv = []
        lp = params["layers"]
        for li in range(cfg.n_layers):
            layer = jax.tree.map(lambda x: x[li], lp)
            k = jnp.einsum("bsd,dhe->bshe", enc,
                           layer["xattn"]["wk"].astype(enc.dtype))
            v = jnp.einsum("bsd,dhe->bshe", enc,
                           layer["xattn"]["wv"].astype(enc.dtype))
            xk.append(k)
            xv.append(v)
        cache["xk"] = jnp.stack(xk)
        cache["xv"] = jnp.stack(xv)

    # --- prefill by stepping the decoder over the prompt ------------------
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = serve(params, prompt[:, t:t + 1], cache, jnp.int32(t))
    t_prefill = time.time() - t0

    # --- optional KV paging through the compressed tensor store -----------
    ratio = None
    kv_err = 0.0
    page_stats = None
    if args.kv_offload:
        import tempfile

        from repro.models.kvcache import (KVPager, offload_prefix,
                                          page_in_blocks_batched)
        from repro.store import PageLostError

        # Only tensors with a kv_len sequence axis at axis 2 are pageable
        # (ssm/rwkv recurrent states have no token axis to evict).
        keys = [k for k in cache
                if k in ("k", "v", "latent", "k_scale", "v_scale")]
        offload_dir = args.kv_offload_dir or tempfile.mkdtemp(
            prefix="kv_blocks_")
        pager = KVPager(offload_dir, codec=kv_codec)
        snapshot = {k: np.asarray(cache[k], np.float32) for k in keys}
        t0 = time.time()
        cache, block_ids = offload_prefix(cache, pager, args.prompt_len,
                                          block_tokens=args.kv_block,
                                          keys=keys)
        t_out = time.time() - t0
        t0 = time.time()
        # Under a non-raise recovery policy a lost block (missing/corrupt
        # archive -> PageLostError, counted in stats["pages_lost"]) keeps
        # its span zeroed and serving continues degraded.
        lost: list = []
        on_lost = (None if args.kv_recovery == "raise"
                   else lambda bid, e: lost.append((bid, e)))
        sched_stats = None
        if args.concurrency > 1:
            # N concurrent decode streams page the same prompt blocks
            # through one shared scheduler: their requests coalesce into
            # class-merged ticks and each distinct block decodes once
            # (lane 0's results are installed into this process' cache).
            import threading

            from repro.serving import DecodeScheduler

            results: dict = {}
            errors: list = []
            with DecodeScheduler(pager,
                                 batch_window_s=args.batch_window) as sched:
                def lane(lane_id: int):
                    futs = [(bid, sched.submit(lane_id, bid))
                            for bid in block_ids]
                    for bid, f in futs:
                        try:
                            tensors = f.result()
                            if lane_id == 0:
                                results[bid] = tensors
                        except PageLostError as e:
                            if lane_id != 0:
                                continue
                            if on_lost is None:
                                errors.append(e)
                            else:
                                lost.append((bid, e))

                lanes = [threading.Thread(target=lane, args=(i,))
                         for i in range(args.concurrency)]
                for th in lanes:
                    th.start()
                for th in lanes:
                    th.join()
                sched_stats = dict(sched.stats)
            if errors:
                raise errors[0]
            for bid, tensors in results.items():
                meta = pager.block_meta(bid)
                span = ((slice(None),) * pager.seq_axis
                        + (slice(meta["lo"], meta["hi"]),))
                for name, block in tensors.items():
                    cache[name] = cache[name].at[span].set(
                        jnp.asarray(block, cache[name].dtype))
        else:
            cache = page_in_blocks_batched(cache, pager, block_ids,
                                           on_lost=on_lost)
        t_in = time.time() - t0
        lost_ids = {bid for bid, _ in lost}
        paged = set()
        for bid in block_ids:
            if bid not in lost_ids:
                paged |= set(pager.block_meta(bid)["names"])
        for name in paged:
            kv_err = max(kv_err, float(np.max(np.abs(
                np.asarray(cache[name], np.float32) - snapshot[name]))))
        ratio = pager.ratio
        page_stats = dict(pager.stats)
        if sched_stats is not None:
            page_stats["scheduler"] = sched_stats
        page_stats["encode_dispatches"] = kv_codec.stats["encode_dispatches"]
        page_stats["encode_fallbacks"] = kv_codec.stats["encode_fallbacks"]
        print(f"[serve] kv offload: {len(block_ids)} blocks x "
              f"{args.kv_block} toks -> {offload_dir} "
              f"({pager.stats['bytes_raw']/2**20:.2f} MiB raw, "
              f"{pager.stats['bytes_compressed']/2**20:.2f} MiB stored, "
              f"ratio {ratio:.2f}x); page-out {t_out:.2f}s, "
              f"page-in {t_in:.2f}s, max err {kv_err:.2e}")
        if sched_stats is not None:
            print(f"[serve] kv scheduler: {args.concurrency} streams x "
                  f"{len(block_ids)} blocks = {sched_stats['requests']} "
                  f"requests -> {sched_stats['batch_dispatches']} batched "
                  f"dispatches ({sched_stats['blocks_decoded']} blocks "
                  f"decoded once; prefix_hits="
                  f"{sched_stats['prefix_hits']}, coalesced="
                  f"{sched_stats['coalesced_requests']})")
        if lost:
            print(f"[serve] kv paging DEGRADED: {len(lost)} block(s) lost "
                  f"(pages_lost={pager.stats['pages_lost']}); their token "
                  f"spans stay zeroed")

    # --- optional cache compress/restore round trip ------------------------
    if args.compress_kv:
        skip = tuple(k for k in cache if k in ("xk", "xv"))
        cc = kvcache.compress_cache(
            {k: v for k, v in cache.items()}, codec=kv_codec, skip=skip)
        restored = kvcache.decompress_cache(cc, codec=kv_codec)
        for name, arr in restored.items():
            kv_err = max(kv_err, float(np.max(np.abs(
                np.asarray(arr, np.float32)
                - np.asarray(cache[name], np.float32)))))
            cache[name] = arr
        ratio = cc.ratio
        print(f"[serve] kv cache compressed {cc.original_bytes/2**20:.1f} MiB"
              f" -> {cc.compressed_bytes/2**20:.1f} MiB "
              f"(ratio {ratio:.2f}x, max err {kv_err:.2e})")

    # --- generation ---------------------------------------------------------
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, args.prompt_len + args.gen_len):
        logits, cache = serve(params, tok, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    t_gen = time.time() - t0
    toks = args.batch * args.gen_len
    print(f"[serve] prefill {args.prompt_len} toks in {t_prefill:.2f}s; "
          f"generated {toks} tokens in {t_gen:.2f}s "
          f"({toks / max(t_gen, 1e-9):.1f} tok/s)")
    return {"ratio": ratio, "kv_err": kv_err, "page_stats": page_stats,
            "tokens": np.asarray(jnp.concatenate(out_tokens, axis=1))}


if __name__ == "__main__":
    main()
