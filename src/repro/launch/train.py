"""Training driver: reduced configs run end-to-end on local hardware; the
full configs use the same code path under the production mesh.

Features exercised here (and by tests/test_train_e2e.py):
  * deterministic sharded data (skip-ahead resume)
  * checkpoint/restart (atomic, optionally SZ-compressed shards)
  * simulated preemption (--preempt-at N exits mid-run; rerunning resumes)
  * gradient compression (--grad-compress, explicit-DP path for small models)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-compress-eb", type=float, default=None)
    ap.add_argument("--ckpt-sharded", action="store_true",
                    help="write checkpoints in the mesh-sharded layout "
                         "(docs/distributed.md) and restore directly into "
                         "the host mesh's shardings")
    ap.add_argument("--ckpt-shards", type=int, default=None,
                    help="shard archives per sharded checkpoint "
                         "(default: one per process)")
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate preemption: exit(17) after this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--opt-int8", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ocfg = adamw.AdamWConfig(
        lr=args.lr, state_dtype="int8" if args.opt_int8 else "float32")

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))

    ckpt_codec = None
    if args.ckpt_compress_eb is not None or args.ckpt_sharded:
        from repro.core import Codec, CodecConfig
        # Sharded layout compresses per tile; default eb if none was given.
        ckpt_codec = Codec(CodecConfig(eb=args.ckpt_compress_eb or 1e-4))
    mgr = (CheckpointManager(args.ckpt_dir, codec=ckpt_codec)
           if args.ckpt_dir else None)
    ckpt_mesh = None
    if args.ckpt_sharded:
        from repro.launch.mesh import make_host_mesh
        ckpt_mesh = make_host_mesh()

    start_step = 0
    params = opt_state = None
    if mgr is not None:
        restored = mgr.restore(mesh=ckpt_mesh)
        if restored is not None:
            params = restored["params"]
            opt_state = restored["opt"]
            start_step = restored["step"] + 1
            print(f"[train] resumed from step {restored['step']}")

    if params is None:
        params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
        opt_state = adamw.init(params, ocfg)

    step_fn = jax.jit(S.make_train_step(cfg, ocfg, n_micro=args.n_micro))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = data.batch_at(step)
        if cfg.family in ("vlm", "encdec"):
            extra_len = 8 if cfg.family == "vlm" else cfg.encoder_seq
            batch = dict(batch)
            batch["extra_embeds"] = jnp.zeros(
                (args.batch, extra_len, cfg.d_model), cfg.cdt)
            if cfg.family == "vlm":
                batch["labels"] = jnp.concatenate(
                    [jnp.full((args.batch, extra_len), -1, jnp.int32),
                     batch["labels"]], axis=1)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(len(losses), 1)
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt*1000:.0f} ms/step)", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step, params, opt_state, mesh=ckpt_mesh,
                     shard_count=args.ckpt_shards)
        if args.preempt_at is not None and step == args.preempt_at:
            print(f"[train] simulated preemption at step {step}")
            sys.exit(17)

    if mgr is not None:
        mgr.save(args.steps - 1, params, opt_state, mesh=ckpt_mesh,
                 shard_count=args.ckpt_shards)
    first, last = losses[0], sum(losses[-5:]) / min(len(losses), 5)
    print(f"[train] done: first loss {first:.4f} -> last(avg5) {last:.4f}")
    return first, last


if __name__ == "__main__":
    main()
