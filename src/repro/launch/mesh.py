"""Production mesh construction.

Never touches jax device state at import time: everything is a function.
Single pod = (16, 16) ("data", "model") = 256 chips (TPU v5e pod slice);
multi-pod adds a leading "pod" axis -> (2, 16, 16) = 512 chips.  The FSDP /
batch dimension is ("pod", "data") combined; "model" carries TP / EP / head
sharding.  Designed so "pod" generalizes to N pods (1000+ nodes): the pod
axis only ever composes with "data", so growing it is a resharding-free
batch-dimension extension.
"""

from __future__ import annotations

import jax


def _auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` where supported.

    ``jax.sharding.AxisType`` (and the ``axis_types=`` parameter) only exist
    in newer jax; older versions behave as if every axis were ``Auto``, so
    omitting the kwarg there is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_kwargs(len(axes)))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         **_auto_axis_kwargs(2))


def batch_axes(mesh) -> tuple:
    """The composite FSDP/batch mesh axes present in ``mesh``."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
