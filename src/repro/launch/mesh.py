"""Production mesh construction.

Never touches jax device state at import time: everything is a function.
Single pod = (16, 16) ("data", "model") = 256 chips (TPU v5e pod slice);
multi-pod adds a leading "pod" axis -> (2, 16, 16) = 512 chips.  The FSDP /
batch dimension is ("pod", "data") combined; "model" carries TP / EP / head
sharding.  Designed so "pod" generalizes to N pods (1000+ nodes): the pod
axis only ever composes with "data", so growing it is a resharding-free
batch-dimension extension.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes(mesh) -> tuple:
    """The composite FSDP/batch mesh axes present in ``mesh``."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
