"""Production mesh construction.

Never touches jax device state at import time: everything is a function.
Single pod = (16, 16) ("data", "model") = 256 chips (TPU v5e pod slice);
multi-pod adds a leading "pod" axis -> (2, 16, 16) = 512 chips.  The FSDP /
batch dimension is ("pod", "data") combined; "model" carries TP / EP / head
sharding.  Designed so "pod" generalizes to N pods (1000+ nodes): the pod
axis only ever composes with "data", so growing it is a resharding-free
batch-dimension extension.
"""

from __future__ import annotations

import jax
import numpy as np


def _auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` where supported.

    ``jax.sharding.AxisType`` (and the ``axis_types=`` parameter) only exist
    in newer jax; older versions behave as if every axis were ``Auto``, so
    omitting the kwarg there is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_kwargs(len(axes)))


class MeshCapacityError(ValueError):
    """A requested mesh shape does not fit the available devices."""


def make_host_mesh(data: int | None = None, model: int = 1, *,
                   devices=None):
    """Small ("data", "model") mesh over local devices (tests / local runs).

    ``data`` defaults to ``len(devices) // model``.  ``devices`` (default:
    ``jax.devices()``) restricts the mesh to a subset -- the sharded-restore
    benchmark builds 1/2/4/8-device meshes on one forced-8-device host this
    way.  A shape that cannot fit raises the named ``MeshCapacityError``
    (requested vs. available) instead of an opaque ``make_mesh`` failure or
    a zero-sized axis.
    """
    devs = list(jax.devices() if devices is None else devices)
    n = len(devs)
    if model < 1:
        raise MeshCapacityError(f"model axis must be >= 1, got {model}")
    if data is None:
        if model > n:
            raise MeshCapacityError(
                f"requested model={model} but only {n} device(s) are "
                f"available; the data axis would be {n} // {model} = 0")
        data = n // model
    if data < 1:
        raise MeshCapacityError(f"data axis must be >= 1, got {data}")
    if data * model > n:
        raise MeshCapacityError(
            f"mesh (data={data}, model={model}) needs {data * model} "
            f"device(s) but only {n} are available")
    if devices is None and data * model == n:
        # Full-host mesh: let make_mesh pick the device order (it optimizes
        # for the physical topology on real accelerators).
        return jax.make_mesh((data, model), ("data", "model"),
                             **_auto_axis_kwargs(2))
    grid = np.array(devs[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def batch_axes(mesh) -> tuple:
    """The composite FSDP/batch mesh axes present in ``mesh``."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh):
    return "model" if "model" in mesh.axis_names else None


def forced_host_devices_env(n: int, *, single_threaded: bool = False,
                            base_env: "dict | None" = None) -> dict:
    """Environment for a subprocess that should see ``n`` host devices.

    ``XLA_FLAGS`` must be set before jax is imported, so multi-device CPU
    tests and the sharded-restore benchmark run in subprocesses built with
    this.  ``single_threaded`` additionally pins each device's compiled
    executables to one thread, so wall-clock scaling across devices
    reflects device count rather than the host's intra-op thread pool.
    """
    env = dict(base_env) if base_env is not None else {}
    flags = [f"--xla_force_host_platform_device_count={n}"]
    if single_threaded:
        flags.append("--xla_cpu_multi_thread_eigen=false")
        env["OMP_NUM_THREADS"] = "1"
        env["OPENBLAS_NUM_THREADS"] = "1"
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    return env
