import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_allow_excess_precision=false "
                           + os.environ.get("XLA_FLAGS", ""))
# --xla_allow_excess_precision=false: stops XLA from keeping f32 "excess
# precision" copies of bf16 remat stacks (observed: a full f32 duplicate of
# the (L, B, S, d) saved-activation stack, 2x the bf16 one).
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first init).  This module proves the distribution config is
coherent: for each cell it AOT-compiles train_step / serve_step against
ShapeDtypeStruct inputs on the production mesh, then records

  * memory_analysis()  -- per-device bytes (proves the cell fits 16 GB HBM)
  * cost_analysis()    -- per-device HLO FLOPs / bytes for §Roofline
  * collective bytes   -- parsed from the partitioned HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operands)

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
                                       # orchestrates one subprocess per cell
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import decode as D
from repro.models import steps as S
from repro.models import transformer as T
from repro.models.config import ModelConfig, count_params
from repro.optim import adamw
from repro.runtime import pspec
from repro.runtime import sharding as shd

V5E = {"flops_bf16": 197e12, "hbm_gbs": 819e9, "ici_link_gbs": 50e9}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64)\[([\d,]*)\]")


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum operand bytes of every collective op in partitioned HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*[^\s]+\s+([a-z\-]+)\(", stripped)
        if not m or m.group(1) not in _COLLECTIVES:
            continue
        op = m.group(1)
        # operands live inside the call parens; shapes appear as dtype[dims]
        paren = stripped[stripped.index("(", stripped.index(op)):]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(paren):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        out[op] += nbytes
        count[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _prod(shape):
    n = 1
    for d in shape:
        n *= int(d)
    return n


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell) -> dict:
    """Abstract inputs for one shape cell (the paper-spec'd entry point)."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            from repro.configs.qwen2_vl_72b import N_PATCHES
            text = s - N_PATCHES
            batch = {
                "tokens": sds((b, text), jnp.int32),
                "labels": sds((b, s), jnp.int32),
                "extra_embeds": sds((b, N_PATCHES, cfg.d_model), cfg.cdt),
            }
        elif cfg.family == "encdec":
            batch = {
                "tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32),
                "extra_embeds": sds((b, cfg.encoder_seq, cfg.d_model),
                                    cfg.cdt),
            }
        else:
            batch = {
                "tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32),
            }
        return batch
    if cell.kind == "decode":
        cache = {k: sds(shape, dt)
                 for k, (shape, dt) in D.cache_spec(cfg, b, s).items()}
        return {
            "token": sds((b, 1), jnp.int32),
            "cache": cache,
            "pos": sds((), jnp.int32),
        }
    raise ValueError(cell.kind)


def opt_config(cfg: ModelConfig) -> adamw.AdamWConfig:
    big = count_params(cfg) > 5e10
    return adamw.AdamWConfig(state_dtype="int8" if big else "float32")


def micro_batches(cfg: ModelConfig) -> int:
    """Gradient-accumulation factor per arch (keeps activation stacks plus
    XLA:CPU's hoisted-conversion copies inside the 16 GB budget)."""
    n = count_params(cfg)
    if n > 3e11:
        return 8
    if n > 5e10:
        return 4
    if n > 8e9:
        return 2
    return 1


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool,
             tp_override: dict | None = None,
             n_micro_override: int | None = None) -> dict:
    cfg = configs.get_config(arch)
    if tp_override:
        cfg = dataclasses.replace(cfg, **tp_override)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    pspec.set_mesh(mesh)
    n_chips = mesh.size
    t0 = time.time()

    params_shape = jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg))
    p_shard = shd.param_shardings(params_shape, mesh)
    result = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod, "n_chips": n_chips,
        "kind": cell.kind,
        "n_params": int(sum(
            _prod(x.shape) for x in jax.tree.leaves(params_shape))),
    }

    if cell.kind == "train":
        ocfg = opt_config(cfg)
        opt_shape = jax.eval_shape(lambda p: adamw.init(p, ocfg),
                                   params_shape)
        o_shard = shd.opt_state_shardings(opt_shape, mesh)
        batch = input_specs(cfg, cell)
        b_shard = shd.batch_shardings(batch, mesh)
        n_micro = n_micro_override or micro_batches(cfg)
        step = S.make_train_step(cfg, ocfg, n_micro=n_micro,
                                 grad_shardings=p_shard)
        result["n_micro"] = n_micro
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           jax.tree.map(lambda _: shd.NamedSharding(
                               mesh, shd.P()), {"ce": 0, "aux": 0, "loss": 0,
                                                "grad_norm": 0,
                                                **({"mtp": 0} if cfg.mtp
                                                   else {})})),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_shape, opt_shape, batch)
    elif cell.kind == "prefill":
        batch = input_specs(cfg, cell)
        b_shard = shd.batch_shardings(batch, mesh)
        step = S.make_prefill_step(cfg)
        if "extra_embeds" in batch:
            def step2(params, tokens, extra):
                return step(params, tokens, extra_embeds=extra)
            jitted = jax.jit(step2, in_shardings=(
                p_shard, b_shard["tokens"], b_shard["extra_embeds"]))
            lowered = jitted.lower(params_shape, batch["tokens"],
                                   batch["extra_embeds"])
        else:
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard["tokens"]))
            lowered = jitted.lower(params_shape, batch["tokens"])
    else:  # decode
        spec = input_specs(cfg, cell)
        c_shard = shd.cache_shardings(spec["cache"], mesh)
        step = S.make_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard,
                          shd.batch_shardings(spec["token"], mesh),
                          c_shard,
                          shd.NamedSharding(mesh, shd.P())),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params_shape, spec["token"], spec["cache"],
                               spec["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    # XLA cost_analysis counts while bodies ONCE (scan-over-layers would be
    # undercounted by ~n_layers); the corrected model multiplies loop bodies
    # by their trip counts (launch/hlo_cost.py).
    from repro.launch import hlo_cost
    corrected = hlo_cost.corrected_costs(hlo)

    model_flops = analytic_model_flops(cfg, cell) / n_chips

    flops = corrected["flops"]
    bytes_acc = corrected["bytes"]
    coll_total = corrected["collective_bytes"]
    result.update({
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "mem": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 - ma.alias_size_in_bytes + ma.temp_size_in_bytes) / 2**30,
                3),
        },
        "cost": {"flops_per_device": flops,
                 "bytes_per_device": bytes_acc,
                 "raw_xla_flops": float(ca.get("flops", 0.0)),
                 "raw_xla_bytes": float(ca.get("bytes accessed", 0.0)),
                 "model_flops_per_device": model_flops,
                 "model_over_hlo": model_flops / flops if flops else 0.0},
        "collectives": dict(coll, loop_corrected_total=coll_total),
        "roofline": roofline_terms(flops, bytes_acc, coll_total),
        "ok": True,
    })
    return result


def analytic_model_flops(cfg: ModelConfig, cell) -> float:
    """MODEL_FLOPS: 6*N*D (train, dense) / 6*N_active*D (MoE) / 2*N per
    decoded token; prefill = 2*N*D.  Attention S^2 terms excluded by
    convention (the ratio vs HLO FLOPs then *shows* attention+remat cost)."""
    n = count_params(cfg)
    if cfg.moe:
        active_frac = (
            cfg.first_k_dense * 1.0 +
            (cfg.n_layers - cfg.first_k_dense)
            * (cfg.n_shared_experts + cfg.top_k) / max(cfg.n_experts, 1)
        ) / cfg.n_layers
        routed_total = count_params(cfg)
        # approximate: embedding+attention always active; experts scaled
        moe_p = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_expert
        n = routed_total - moe_p + int(
            moe_p * (cfg.top_k + cfg.n_shared_experts) / cfg.n_experts)
    d_tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n * d_tokens
    if cell.kind == "prefill":
        return 2.0 * n * d_tokens
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes_dev: float):
    """Three roofline terms in seconds (per-device quantities in, so chips
    cancel: T = per-device work / per-chip peak)."""
    t_c = flops_dev / V5E["flops_bf16"]
    t_m = bytes_dev / V5E["hbm_gbs"]
    t_l = coll_bytes_dev / V5E["ici_link_gbs"]
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))
    return {
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": dom[1],
        "bound_step_s": max(t_c, t_m, t_l),
    }


# ---------------------------------------------------------------------------
# CLI / orchestration
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=val (val parsed as python "
                         "literal), e.g. --override kv_quant=True")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    if args.all:
        orchestrate(args)
        return

    import ast
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = ast.literal_eval(v)
    res = run_cell(args.arch, args.shape, args.multi_pod,
                   tp_override=overrides or None,
                   n_micro_override=args.n_micro)
    print(json.dumps(res))
    # Paper-spec'd prints:
    sys.stderr.write(
        f"# {args.arch} x {args.shape} mesh={res['mesh']}: "
        f"peak {res['mem']['peak_per_device_gib']} GiB/device, "
        f"{res['cost']['flops_per_device']:.3e} flops/device, "
        f"coll {res['collectives']['total']/2**20:.1f} MiB/device, "
        f"dominant={res['roofline']['dominant']}\n")


def orchestrate(args):
    done = set()
    try:
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["multi_pod"]))
    except FileNotFoundError:
        pass

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    todo = [(a, s, mp)
            for (a, s, _skip) in configs.cells()
            for mp in meshes
            if (a, s, mp) not in done]
    print(f"{len(todo)} cells to run -> {args.out}")
    for arch, shape, mp in todo:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if p.returncode == 0:
                rec = json.loads(p.stdout.strip().splitlines()[-1])
            else:
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "ok": False, "error": p.stderr[-2000:]}
        except subprocess.TimeoutExpired:
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "ok": False, "error": f"timeout {args.timeout}s"}
        rec["wall_s"] = round(time.time() - t0, 1)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {arch:20s} {shape:12s} mp={mp} "
              f"({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
