"""Refcounted decoded-block cache: hot shared prefixes decode once.

Serving traffic is prefix-heavy: thousands of sessions open with the same
system prompt, so their first KV page-ins all name blocks with identical
*content* (``KVPager.block_key`` -- the sorted (tensor name, chunk digest)
pairs of a block's archive).  ``BlockCache`` keys decoded blocks by that
content identity, so the first session's decode serves every later session
from memory and the scheduler's "decoded exactly once per distinct block"
invariant holds under arbitrary interleaving.

Admission / eviction policy (the compressed pool is bounded):

* **capacity** -- decoded bytes are bounded by ``capacity_bytes``; inserts
  evict least-recently-used entries to make room.
* **pinned-in-flight protection** -- entries referenced by an in-flight
  scheduler tick are pinned (refcounted) and NEVER evicted, so capacity
  pressure from one tick cannot thrash a block another tick is about to
  hand out (which would silently break decode-once).
* **admission** -- a block larger than the whole capacity is served but
  not cached (``stats["admission_rejects"]``), instead of wiping the
  cache for one oversized tenant.

Thread-safe; all operations are O(1) amortized.
"""

from __future__ import annotations

import collections
import dataclasses
import threading


@dataclasses.dataclass
class _Entry:
    value: dict          # {tensor name: decoded device array}
    nbytes: int
    pins: int = 0


class BlockCache:
    """LRU cache of decoded KV blocks with refcount (pin) protection."""

    def __init__(self, capacity_bytes: int = 1 << 30):
        if capacity_bytes < 0:
            raise ValueError(
                f"capacity_bytes must be >= 0, got {capacity_bytes}")
        self.capacity_bytes = capacity_bytes
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "inserts": 0, "evictions": 0,
                      "admission_rejects": 0, "resident_bytes": 0}

    def __len__(self):
        with self._lock:
            return len(self._entries)

    def __contains__(self, key):
        with self._lock:
            return key in self._entries

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self.stats["resident_bytes"]

    def acquire(self, key):
        """Look up + pin in one step.  Returns the decoded block (pinned:
        caller must ``release``) or ``None`` on a miss (counted)."""
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                self.stats["misses"] += 1
                return None
            self._entries.move_to_end(key)
            e.pins += 1
            self.stats["hits"] += 1
            return e.value

    def insert(self, key, value: dict, nbytes: int, *,
               pinned: bool = True) -> bool:
        """Insert a freshly decoded block (pinned by default: the inserting
        tick is still in flight).  Returns False when admission rejects it
        (larger than the whole cache) or the key is already present (the
        existing entry wins and is pinned instead -- two ticks may race to
        decode the same content when it was evicted between them).
        """
        with self._lock:
            if nbytes > self.capacity_bytes:
                self.stats["admission_rejects"] += 1
                return False
            e = self._entries.get(key)
            if e is not None:
                self._entries.move_to_end(key)
                if pinned:
                    e.pins += 1
                return False
            self._entries[key] = _Entry(value, int(nbytes),
                                        1 if pinned else 0)
            self.stats["inserts"] += 1
            self.stats["resident_bytes"] += int(nbytes)
            self._evict_locked()
            return True

    def release(self, key):
        """Unpin one reference; unknown keys (already evicted after their
        pins dropped) are ignored."""
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e.pins > 0:
                e.pins -= 1
            self._evict_locked()

    def _evict_locked(self):
        """Evict LRU *unpinned* entries until within capacity.  If every
        resident entry is pinned the cache may transiently exceed capacity
        -- in-flight ticks always win over the bound."""
        if self.stats["resident_bytes"] <= self.capacity_bytes:
            return
        for key in list(self._entries):
            if self.stats["resident_bytes"] <= self.capacity_bytes:
                break
            e = self._entries[key]
            if e.pins > 0:
                continue
            del self._entries[key]
            self.stats["resident_bytes"] -= e.nbytes
            self.stats["evictions"] += 1
