"""Serving sessions and their latency accounting.

A ``Session`` is one decode stream: it arrives (Poisson in the load
generator), needs a set of KV blocks paged in before its first token can be
computed (some shared with other sessions -- the hot prefix -- some unique),
and reports **time-to-first-token** (TTFT): arrival -> every needed block
resolved.  The scheduler never sees sessions directly, only (session id,
block id) requests; this module is the bookkeeping around them.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class Session:
    """One decode stream's lifecycle, timed against a shared clock."""

    sid: int
    block_ids: list                  # blocks needed before the first token
    arrival_s: float = 0.0           # offset from the run's t0
    t_first_token: "float | None" = None   # offset; None until served
    error: "Exception | None" = None

    @property
    def done(self) -> bool:
        return self.t_first_token is not None or self.error is not None

    @property
    def ttft_s(self) -> "float | None":
        """Arrival -> first token, seconds (None until served)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_s

    def mark_served(self, t0: float):
        self.t_first_token = time.perf_counter() - t0


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]) of a non-empty sequence."""
    xs = sorted(xs)
    if not xs:
        raise ValueError("percentile of empty sequence")
    rank = max(0, min(len(xs) - 1, round(p / 100.0 * (len(xs) - 1))))
    return float(xs[rank])


def summarize_ttft(sessions) -> dict:
    """p50/p99/mean TTFT (ms) over the served sessions + failure count."""
    served = [s.ttft_s for s in sessions if s.ttft_s is not None]
    failed = sum(1 for s in sessions if s.error is not None)
    if not served:
        return {"n": 0, "failed": failed, "p50_ms": float("nan"),
                "p99_ms": float("nan"), "mean_ms": float("nan")}
    return {
        "n": len(served),
        "failed": failed,
        "p50_ms": percentile(served, 50) * 1e3,
        "p99_ms": percentile(served, 99) * 1e3,
        "mean_ms": sum(served) / len(served) * 1e3,
    }
