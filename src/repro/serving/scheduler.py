"""Continuous batching of KV page-in decode streams across sessions.

``serve --kv-offload`` demand-pages one session's blocks synchronously on
its own critical path: every block is one archive open + one decode
dispatch chain, and the decoder idles between requests.  The paper's whole
premise is the opposite -- keep the decoder saturated with few, large
dispatches.  ``DecodeScheduler`` applies that to serving:

* **continuous batching** -- page-in requests from many concurrent
  sessions that arrive within one ``batch_window_s`` coalesce into a tick;
  each tick decodes through ONE class-merged ``decompress_batch`` over
  every tensor of every requested block (``KVPager.decode_staged``), so
  dispatch count scales with CR classes per tick, not with sessions.
* **async double buffering** -- the store reader already overlaps disk
  reads with decode *within* one archive; the scheduler extends that
  across requests: tick N+1's host stage (archive read + CRC + plan
  resolution, on the I/O thread) runs while tick N's device decode runs
  on the scheduler thread, so session N+1's blocks are staged while
  session N computes.
* **prefix-aware sharing** -- blocks are identified by content
  (``KVPager.block_key``); a hot shared prompt prefix decodes exactly
  once into the refcounted ``BlockCache`` and every later session is
  served from memory (``stats["prefix_hits"]``).
* **fairness / admission** -- at most ``max_blocks_per_session_per_tick``
  blocks of one session enter a tick (the rest stay queued, counted in
  ``stats["deferred"]``), so a 1-block session is never starved behind a
  1000-block restore; the decoded pool is capacity-bounded with LRU
  eviction and pinned-in-flight protection (``prefix_cache.BlockCache``).

Failures stay named: a lost block (``PageLostError`` -- missing / corrupt
/ guard-tripped archive, already evicted + counted by the pager) fails
only the futures of the sessions that asked for it; batch-mates decode on.
"""

from __future__ import annotations

import collections
import concurrent.futures as futures
import dataclasses
import threading
import time

from repro.serving.prefix_cache import BlockCache
from repro.store.paging import KVPager, PageLostError

DEFAULT_BATCH_WINDOW_S = 0.002


@dataclasses.dataclass
class _Request:
    sid: int
    block_id: int
    future: futures.Future
    t_submit: float


@dataclasses.dataclass
class _Tick:
    """One batching round: cache hits already resolved (pinned until the
    tick retires), misses staging on the I/O thread."""

    hit_keys: list                    # pinned cache keys to release
    misses: dict                      # key -> (block_id, [requests])
    staged: "futures.Future | None"   # -> {key: StagedBlock | PageLostError}


class DecodeScheduler:
    """Batch + overlap + dedupe KV page-in decodes for many sessions.

    One scheduler owns one shared ``Codec`` + ``KVPager`` (the pager's
    codec): requests from any thread via :meth:`submit` return futures that
    resolve to the block's decoded tensors ``{name: device array}``.

    ``overlap=False`` degrades to stage-then-decode on one thread (the
    ablation the serving benchmark measures); batching and sharing remain.
    """

    def __init__(self, pager: KVPager, *,
                 batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                 cache_bytes: int = 1 << 30,
                 max_blocks_per_session_per_tick: int = 8,
                 overlap: bool = True):
        if batch_window_s < 0:
            raise ValueError(
                f"batch_window_s must be >= 0, got {batch_window_s}")
        if max_blocks_per_session_per_tick < 1:
            raise ValueError("max_blocks_per_session_per_tick must be >= 1, "
                             f"got {max_blocks_per_session_per_tick}")
        self.pager = pager
        self.codec = pager.codec
        self.cache = BlockCache(cache_bytes)
        self.batch_window_s = batch_window_s
        self.fair_cap = max_blocks_per_session_per_tick
        self.overlap = overlap
        self.stats = {"requests": 0, "ticks": 0, "batch_dispatches": 0,
                      "blocks_decoded": 0, "prefix_hits": 0,
                      "coalesced_requests": 0, "deferred": 0,
                      "blocks_lost": 0, "max_tick_requests": 0}
        self._queue: collections.deque = collections.deque()
        self._cond = threading.Condition()
        # key -> the (shared, mutable) request list of an in-flight decode:
        # requests for a block whose decode is already staged/decoding JOIN
        # it instead of re-staging (scheduler thread only -- no lock).
        self._pending: dict = {}
        self._stopping = False
        self._io = (futures.ThreadPoolExecutor(
            1, thread_name_prefix="serving-stage") if overlap else None)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="serving-scheduler")
        self._thread.start()

    # -- client surface ------------------------------------------------------

    def submit(self, sid: int, block_id: int) -> futures.Future:
        """Enqueue one block page-in for a session; returns a future that
        resolves to ``{name: decoded array}`` or raises ``PageLostError``.
        """
        fut: futures.Future = futures.Future()
        with self._cond:
            if self._stopping:
                raise RuntimeError("DecodeScheduler is closed")
            self._queue.append(_Request(sid, block_id, fut,
                                        time.perf_counter()))
            self.stats["requests"] += 1
            self._cond.notify_all()
        return fut

    def fetch(self, sid: int, block_ids) -> dict:
        """Blocking convenience: submit every block and wait.  Returns
        {block_id: {name: array}}; the first lost block raises."""
        futs = [(bid, self.submit(sid, bid)) for bid in block_ids]
        return {bid: f.result() for bid, f in futs}

    def close(self):
        """Drain the queue, retire in-flight ticks, stop the thread."""
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        self._thread.join()
        if self._io is not None:
            self._io.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    # -- scheduler loop ------------------------------------------------------

    def _run(self):
        inflight: collections.deque = collections.deque()
        while True:
            batch = self._collect(bool(inflight))
            if batch:
                inflight.append(self._assemble(batch))
            # Keep exactly one tick staging in the background under
            # sustained load (decode of tick N overlaps stage of tick N+1);
            # drain fully when traffic pauses.
            while inflight and (len(inflight) > 1 or not batch):
                self._finish(inflight.popleft())
            with self._cond:
                if self._stopping and not self._queue and not inflight:
                    return

    def _collect(self, have_inflight: bool) -> list:
        """Wait for traffic, let the batching window coalesce arrivals,
        then drain the queue under the per-session fairness cap."""
        with self._cond:
            if not self._queue and not have_inflight and not self._stopping:
                while not self._queue and not self._stopping:
                    self._cond.wait(0.1)
            elif not self._queue and not self._stopping:
                # Inflight ticks exist: bounded wait so they retire even if
                # no more traffic arrives.
                self._cond.wait(self.batch_window_s or 0.001)
        if self.batch_window_s > 0 and not self._stopping:
            time.sleep(self.batch_window_s)
        with self._cond:
            taken: list = []
            left: collections.deque = collections.deque()
            per_sid: collections.Counter = collections.Counter()
            while self._queue:
                r = self._queue.popleft()
                if per_sid[r.sid] < self.fair_cap:
                    per_sid[r.sid] += 1
                    taken.append(r)
                else:
                    left.append(r)
            self.stats["deferred"] += len(left)
            self._queue = left
        if taken:
            self.stats["max_tick_requests"] = max(
                self.stats["max_tick_requests"], len(taken))
        return taken

    def _assemble(self, reqs: list) -> _Tick:
        """Group a tick's requests by block *content*, resolve cache hits
        immediately (best TTFT), kick off staging for the misses."""
        by_key: dict = {}
        for r in reqs:
            try:
                key = self.pager.block_key(r.block_id)
            except PageLostError as e:
                self.stats["blocks_lost"] += 1
                r.future.set_exception(e)
                continue
            if key in by_key:
                by_key[key][1].append(r)
            else:
                by_key[key] = (r.block_id, [r])
        hit_keys, misses = [], {}
        for key, (bid, rs) in by_key.items():
            pending = self._pending.get(key)
            if pending is not None:
                # A previous tick is already decoding this content: join it
                # (continuous batching across ticks, decode still happens
                # exactly once).
                self.stats["prefix_hits"] += len(rs)
                pending.extend(rs)
                continue
            val = self.cache.acquire(key)
            if val is not None:
                self.stats["prefix_hits"] += len(rs)
                for r in rs:
                    r.future.set_result(val)
                hit_keys.append(key)
            else:
                # One decode serves every same-tick duplicate of this key.
                self.stats["coalesced_requests"] += len(rs) - 1
                misses[key] = (bid, rs)
                self._pending[key] = rs
        staged = (self._io.submit(self._stage_keys, misses)
                  if self._io is not None and misses else None)
        return _Tick(hit_keys=hit_keys, misses=misses, staged=staged)

    def _stage_keys(self, misses: dict) -> dict:
        """Host stage (I/O thread): archive read + CRC + plan per miss.
        Failures travel as values -- the scheduler thread applies them."""
        out = {}
        for key, (bid, _) in misses.items():
            try:
                out[key] = self.pager.stage(bid)
            except PageLostError as e:
                out[key] = e
        return out

    def _finish(self, tick: _Tick):
        """Decode a tick's staged misses in one merged dispatch set, publish
        results, unpin everything the tick touched."""
        staged = (tick.staged.result() if tick.staged is not None
                  else self._stage_keys(tick.misses))
        ok = {k: s for k, s in staged.items()
              if not isinstance(s, Exception)}
        lost = {k: e for k, e in staged.items() if isinstance(e, Exception)}

        decode_lost: dict = {}
        decoded = self.pager.decode_staged(
            ok.values(),
            on_lost=lambda bid, e: decode_lost.setdefault(bid, e))
        if ok:
            self.stats["batch_dispatches"] += 1

        for key, sb in ok.items():
            tensors = decoded.get(sb.block_id)
            if tensors is None:
                lost[key] = decode_lost.get(sb.block_id) or PageLostError(
                    f"kv block {sb.block_id} lost in decode",
                    block_id=sb.block_id)
                continue
            self.stats["blocks_decoded"] += 1
            self.cache.insert(key, tensors, sb.decoded_bytes, pinned=True)
            # The pending list may have grown since assembly: later ticks'
            # requests joined this decode instead of re-staging.
            for r in self._pending.pop(key, tick.misses[key][1]):
                r.future.set_result(tensors)
        for key, e in lost.items():
            self.stats["blocks_lost"] += 1
            for r in self._pending.pop(key, tick.misses[key][1]):
                r.future.set_exception(e)

        for key in tick.hit_keys:
            self.cache.release(key)
        for key in ok:
            self.cache.release(key)
        self.stats["ticks"] += 1
