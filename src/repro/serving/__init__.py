"""Serving scheduler: continuous batching of decode streams.

The paper keeps one decode saturated; this package keeps the *decoder*
saturated across many concurrent sessions -- batched page-in dispatches,
stage/decode double buffering, prefix-aware block sharing, and a bounded
decoded-block pool.  See docs/serving.md.
"""

from repro.serving.loadgen import Corpus, build_corpus, run_load
from repro.serving.prefix_cache import BlockCache
from repro.serving.scheduler import DecodeScheduler
from repro.serving.sessions import Session, percentile, summarize_ttft

__all__ = [
    "BlockCache",
    "Corpus",
    "DecodeScheduler",
    "Session",
    "build_corpus",
    "percentile",
    "run_load",
    "summarize_ttft",
]
