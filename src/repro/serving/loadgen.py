"""Poisson-arrival load generator: the "millions of users" story, measured.

Builds a corpus of offloaded KV blocks -- a hot prompt prefix shared by
every session plus per-session unique blocks -- then replays N concurrent
sessions against either:

* ``mode="baseline"`` -- the pre-scheduler serving shape: each session
  demand-pages its blocks synchronously on its own critical path
  (``KVPager.fetch`` per block, per session; the shared prefix is
  re-decoded by every session), or
* ``mode="scheduler"`` -- the ``DecodeScheduler``: requests within a
  batching window coalesce into class-merged dispatches, tick N+1 stages
  while tick N decodes, and the shared prefix decodes exactly once.

Reports p50/p99 time-to-first-token and decode dispatches per request.
Structural invariants (decode-once, dispatch reduction) are deterministic
under a fixed seed and asserted by ``--check`` (the CI smoke tier) and by
``tests/test_serving.py``; the latency percentiles are what
``benchmarks/serving_load.py`` records.

Usage:
  PYTHONPATH=src python -m repro.serving.loadgen --sessions 100 --seed 0 \\
      --check
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.codec import Codec, CodecConfig
from repro.serving.scheduler import DecodeScheduler
from repro.serving.sessions import Session, summarize_ttft
from repro.store.paging import KVPager


@dataclasses.dataclass
class Corpus:
    """Offloaded KV blocks on disk + who needs which."""

    dir: str
    config: CodecConfig
    metas: dict                  # block_id -> pager meta (adoptable)
    prefix_ids: list             # blocks every session shares
    unique_ids: dict             # sid -> this session's own blocks

    @property
    def n_sessions(self) -> int:
        return len(self.unique_ids)

    def session_blocks(self, sid: int) -> list:
        return list(self.prefix_ids) + list(self.unique_ids[sid])

    @property
    def n_distinct_blocks(self) -> int:
        return len(self.prefix_ids) + sum(
            len(v) for v in self.unique_ids.values())

    @property
    def n_block_requests(self) -> int:
        return sum(len(self.session_blocks(s)) for s in self.unique_ids)


def _kv_tensors(rng, n_tokens: int, layers: int, heads: int, dim: int):
    """A smooth-along-S synthetic KV pair, shaped like ``models/decode``."""
    shape = (layers, 1, n_tokens, heads, dim)
    walk = np.cumsum(rng.normal(size=shape).astype(np.float32), axis=2)
    return {"k": jnp.asarray(0.1 * walk),
            "v": jnp.asarray(0.1 * walk[::-1] + rng.normal(
                size=shape).astype(np.float32) * 0.01)}


def build_corpus(directory: str, *, n_sessions: int = 100,
                 prefix_blocks: int = 4, unique_blocks: int = 1,
                 tokens_per_block: int = 8, layers: int = 2, heads: int = 2,
                 head_dim: int = 8, seed: int = 0,
                 config: "CodecConfig | None" = None) -> Corpus:
    """Offload the shared prefix + per-session blocks into one pager dir."""
    config = config if config is not None else CodecConfig()
    pager = KVPager(directory, codec=Codec(config))
    rng = np.random.default_rng(seed)

    prefix_ids = []
    cache = _kv_tensors(rng, prefix_blocks * tokens_per_block, layers,
                        heads, head_dim)
    for i in range(prefix_blocks):
        cache, bid = pager.offload(cache, i * tokens_per_block,
                                   (i + 1) * tokens_per_block)
        prefix_ids.append(bid)

    unique_ids: dict = {}
    for sid in range(n_sessions):
        cache = _kv_tensors(rng, unique_blocks * tokens_per_block, layers,
                            heads, head_dim)
        ids = []
        for i in range(unique_blocks):
            cache, bid = pager.offload(cache, i * tokens_per_block,
                                       (i + 1) * tokens_per_block)
            ids.append(bid)
        unique_ids[sid] = ids

    metas = {bid: pager.block_meta(bid) for bid in pager.resident_blocks}
    return Corpus(dir=directory, config=config, metas=metas,
                  prefix_ids=prefix_ids, unique_ids=unique_ids)


def run_load(corpus: Corpus, *, mode: str = "scheduler",
             rate_per_s: float = 400.0, seed: int = 0,
             batch_window_s: float = 0.002, cache_bytes: int = 1 << 30,
             overlap: bool = True,
             max_blocks_per_session_per_tick: int = 8) -> dict:
    """Replay the corpus' sessions with Poisson arrivals; returns metrics.

    A fresh ``Codec`` (fresh plan cache) + ``KVPager`` are built per run so
    baseline and scheduler modes start equally cold.
    """
    if mode not in ("baseline", "scheduler"):
        raise ValueError(f"unknown mode {mode!r}; valid: baseline, "
                         f"scheduler")
    codec = Codec(corpus.config)
    pager = KVPager(corpus.dir, codec=codec)
    for bid, meta in corpus.metas.items():
        pager.adopt_block(bid, meta)

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s,
                                         corpus.n_sessions))
    sessions = [Session(sid=sid, block_ids=corpus.session_blocks(sid),
                        arrival_s=float(t))
                for sid, t in zip(sorted(corpus.unique_ids), arrivals)]

    sched = (DecodeScheduler(
        pager, batch_window_s=batch_window_s, cache_bytes=cache_bytes,
        overlap=overlap,
        max_blocks_per_session_per_tick=max_blocks_per_session_per_tick)
        if mode == "scheduler" else None)

    def worker(s: Session, t0: float):
        try:
            if sched is not None:
                sched.fetch(s.sid, s.block_ids)
            else:
                for bid in s.block_ids:
                    pager.fetch(bid)
            s.mark_served(t0)
        except Exception as e:       # lost blocks -> failed session, counted
            s.error = e

    before = dict(codec.backend.stats)
    threads = []
    t0 = time.perf_counter()
    for s in sessions:
        delay = t0 + s.arrival_s - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        s.arrival_s = time.perf_counter() - t0   # actual spawn offset
        th = threading.Thread(target=worker, args=(s, t0), daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join()
    wall_s = time.perf_counter() - t0
    if sched is not None:
        sched.close()
    delta = {k: codec.backend.stats[k] - before.get(k, 0)
             for k in codec.backend.stats}

    n_req = corpus.n_block_requests
    out = {
        "mode": mode, "overlap": overlap, "wall_s": wall_s,
        "ttft": summarize_ttft(sessions),
        "block_requests": n_req,
        "decode_dispatches": delta["decode_write_dispatches"],
        "plan_builds": delta["plan_builds"],
        "dispatches_per_request":
            delta["decode_write_dispatches"] / max(n_req, 1),
        "pager": dict(pager.stats),
    }
    if sched is not None:
        out["scheduler"] = dict(sched.stats)
        out["cache"] = dict(sched.cache.stats)
    return out


def check_invariants(corpus: Corpus, base: dict, schd: dict):
    """The structural wins the scheduler must deliver, deterministically.

    Raises ``AssertionError`` naming the violated invariant; timing
    percentiles are deliberately NOT checked here (CI timers are noisy) --
    the benchmark records them.
    """
    for r in (base, schd):
        assert r["ttft"]["failed"] == 0, \
            f"{r['mode']}: {r['ttft']['failed']} sessions failed"
        assert r["ttft"]["n"] == corpus.n_sessions, \
            f"{r['mode']}: served {r['ttft']['n']} of {corpus.n_sessions}"
    st = schd["scheduler"]
    assert st["blocks_decoded"] == corpus.n_distinct_blocks, (
        f"every distinct block must decode exactly once: decoded "
        f"{st['blocks_decoded']}, distinct {corpus.n_distinct_blocks}")
    shared = (corpus.n_sessions - 1) * len(corpus.prefix_ids)
    got = st["prefix_hits"] + st["coalesced_requests"]
    assert got == shared, (
        f"shared-prefix requests must be served without re-decode: "
        f"hits+coalesced = {got}, expected {shared}")
    assert schd["decode_dispatches"] < base["decode_dispatches"], (
        f"batching must reduce decode dispatches: scheduler "
        f"{schd['decode_dispatches']} vs baseline "
        f"{base['decode_dispatches']}")


def _fmt(r: dict) -> str:
    t = r["ttft"]
    return (f"[loadgen] {r['mode']:<9} overlap={str(r['overlap']):<5} "
            f"n={t['n']} failed={t['failed']} "
            f"ttft p50={t['p50_ms']:.1f}ms p99={t['p99_ms']:.1f}ms "
            f"dispatches/req={r['dispatches_per_request']:.3f} "
            f"wall={r['wall_s']:.2f}s")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Poisson load generator for the serving scheduler")
    ap.add_argument("--sessions", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=400.0,
                    help="mean session arrivals per second")
    ap.add_argument("--prefix-blocks", type=int, default=4)
    ap.add_argument("--unique-blocks", type=int, default=1)
    ap.add_argument("--tokens-per-block", type=int, default=8)
    ap.add_argument("--batch-window", type=float, default=0.002,
                    help="scheduler batching window (seconds)")
    ap.add_argument("--cache-mib", type=float, default=1024.0,
                    help="decoded-block cache capacity")
    ap.add_argument("--check", action="store_true",
                    help="assert the structural invariants (CI smoke): "
                         "decode-once, prefix sharing, dispatch reduction")
    args = ap.parse_args(argv)

    with tempfile.TemporaryDirectory(prefix="serving_loadgen_") as d:
        corpus = build_corpus(d, n_sessions=args.sessions,
                              prefix_blocks=args.prefix_blocks,
                              unique_blocks=args.unique_blocks,
                              tokens_per_block=args.tokens_per_block,
                              seed=args.seed)
        base = run_load(corpus, mode="baseline", rate_per_s=args.rate,
                        seed=args.seed)
        schd = run_load(corpus, mode="scheduler", rate_per_s=args.rate,
                        seed=args.seed, batch_window_s=args.batch_window,
                        cache_bytes=int(args.cache_mib * 2**20))
        print(_fmt(base))
        print(_fmt(schd))
        st = schd["scheduler"]
        print(f"[loadgen] scheduler: ticks={st['ticks']} "
              f"batch_dispatches={st['batch_dispatches']} "
              f"blocks_decoded={st['blocks_decoded']} "
              f"prefix_hits={st['prefix_hits']} "
              f"coalesced={st['coalesced_requests']} "
              f"deferred={st['deferred']}")
        if args.check:
            check_invariants(corpus, base, schd)
            print("[loadgen] CHECK OK: decode-once, prefix sharing, "
                  "dispatch reduction all hold")
    return base, schd


if __name__ == "__main__":
    main()
