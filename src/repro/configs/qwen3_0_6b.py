"""qwen3-0.6b [dense] -- 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf-verified]

Qwen3 uses an explicit head_dim=128 (decoupled from d_model/n_heads) and
per-head RMS qk-norm; the 0.6B ties embeddings."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151936,
    d_head=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    act="silu",
)
