"""h2o-danube-1.8b [dense] -- 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf-verified]

The SWA window makes this arch sub-quadratic => it runs the long_500k cell
(DESIGN.md §5)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    d_head=80,
    swa_window=4096,
    rope_theta=1e4,
    act="silu",
)
