"""whisper-base [audio] -- 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865;
encoder-decoder, conv frontend (stub).  [arXiv:2212.04356; unverified]

The conv1d frontend is a stub: ``input_specs()`` supplies precomputed frame
embeddings (B, 1500, d_model) straight into the encoder.  Decoder is causal
with cross-attention; decode shapes run the text decoder against a cached
encoder (DESIGN.md §5)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    d_head=64,
    encoder_layers=6,
    encoder_seq=1500,
    act="gelu",
    mlp_type="plain",
    frontend="audio_stub",
)
