"""qwen2-vl-72b [vlm] -- 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf-verified]

Transformer BACKBONE only: the vision frontend is a stub --
``input_specs()`` supplies precomputed patch embeddings (B, 256, d_model)
prepended to the token stream; M-RoPE runs with coincident t/h/w ids for
text and the stub's linear ids for patches (DESIGN.md §5)."""

from repro.models.config import ModelConfig

N_PATCHES = 256  # stub patch-embedding count per sample

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    d_head=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    act="silu",
    param_dtype="bfloat16",
)
