"""zamba2-7b [hybrid] -- 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention block.
[arXiv:2411.15242; unverified]

Mamba2 blocks (expand=2, head P=64) with the weight-*shared* full-attention
block applied every 6 layers (Zamba2's shared-transformer design; the
per-invocation LoRA deltas are omitted -- DESIGN.md §5).  Sub-quadratic
(constant-size SSM state + periodic attention over a bounded window at
decode) => runs long_500k."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid_ssm",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    d_head=112,
    ssm_state=64,
    ssm_heads=112,           # 2*d_model / 64
    ssm_chunk=128,
    hybrid_attn_every=6,
    swa_window=4096,         # bound the shared-attn cache for long contexts
    act="silu",
    param_dtype="bfloat16",
)
