"""deepseek-v3-671b [moe] -- 61L d_model=7168 128H (MLA) d_ff=2048(expert)
vocab=129280, MoE 256e top-8; MLA, 1 shared + 256 routed, MTP.
[arXiv:2412.19437; hf-verified]

Notes:
  * the assigned d_ff=2048 is the MoE expert width; the first_k_dense=3
    prefix layers use the dense FFN width 18432 (d_ff below), matching the
    HF config (intermediate_size vs moe_intermediate_size);
  * MLA dims: q_lora 1536, kv_lora 512, rope 64, nope 128, v 128 -- the
    decode cache stores only the 576-wide latent per token;
  * bf16 params + int8 optimizer state are required to fit the 256-chip
    single-pod mesh (DESIGN.md §6)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,              # dense-prefix FFN width (see module docstring)
    vocab=129280,
    d_head=128,
    rope_theta=1e4,
    moe=True,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    d_expert=2048,
    first_k_dense=3,
    capacity_factor=1.0,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    mtp=True,
    act="silu",
    param_dtype="bfloat16",
)
