"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from repro.configs import shapes  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeCell  # noqa: F401

from repro.configs.qwen3_0_6b import CONFIG as _qwen3
from repro.configs.starcoder2_15b import CONFIG as _sc2
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.qwen2_5_3b import CONFIG as _qwen25
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2vl
from repro.configs.whisper_base import CONFIG as _whisper

REGISTRY = {
    c.name: c
    for c in [_qwen3, _sc2, _danube, _qwen25, _zamba2, _qwen2moe, _dsv3,
              _rwkv6, _qwen2vl, _whisper]
}

ARCH_IDS = list(REGISTRY)


def get_config(arch: str):
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return REGISTRY[arch]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule."""
    out = []
    for name, cfg in REGISTRY.items():
        for sname, cell in SHAPES.items():
            skip = sname == "long_500k" and not cfg.is_subquadratic
            if skip and not include_skipped:
                continue
            out.append((name, sname, skip))
    return out
