"""starcoder2-15b [dense] -- 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA, RoPE.  [arXiv:2402.19173; hf-verified]

StarCoder2 uses learned bias on QKV and a GELU MLP; we keep the framework's
gated-MLP form with gelu activation (d_ff as specified) -- noted in
DESIGN.md as a uniform-substrate simplification."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    d_head=128,
    qkv_bias=True,
    rope_theta=1e5,
    act="gelu",
    mlp_type="plain",
    param_dtype="bfloat16",
)
