"""qwen2-moe-a2.7b [moe] -- 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4; 4 shared + 60 routed.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf-verified]

The assigned d_ff=1408 is the per-expert width (moe_intermediate_size);
the shared expert is 4x that (5632), expressed as n_shared_experts=4."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    d_head=128,
    qkv_bias=True,
    rope_theta=1e6,
    moe=True,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    d_expert=1408,
    act="silu",
)
