"""Assigned input-shape set (identical for every LM arch; 4 cells each).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``.  ``long_500k`` requires sub-quadratic
attention -- skipped for pure full-attention archs (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}
