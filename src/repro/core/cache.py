"""Plan / LUT cache and the content digests that key it.

Promoted out of ``repro.store`` so plan reuse is a property of the *codec*,
not of the archive reader: every consumer that decodes through a
``repro.core.Codec`` (checkpoint restore, KV paging, direct library calls)
shares one digest-keyed cache.

Two maps, both keyed by content digests:

* **codebooks** -- codebook digest -> materialized ``Codebook`` (decode LUT
  included).  Archives store only the tiny encoder tables; the
  ``2**max_len``-entry decode LUT is derived on first use and shared by
  every chunk (and every archive) with the same histogram.
* **plans** -- (chunk digest, method, t_high) -> ``DecoderPlan``.  A chunk
  digest names the *decode problem* (payload bytes + framing + codebook),
  so a cached plan is valid for any tensor with that content -- whether it
  arrived from an archive chunk or an in-memory ``Compressed``.  Plans are
  backend-portable (asserted by the pipeline tests), so the key
  deliberately omits the backend.

The cache is bounded (LRU on plans) because KV paging can stream an
unbounded number of distinct blocks through one process.
"""

from __future__ import annotations

import collections
import hashlib
import struct
import threading
import zlib

import numpy as np


def crc32_arrays(*arrays) -> int:
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc & 0xFFFFFFFF


def payload_crc(units, gaps, outlier_pos, outlier_val) -> int:
    """Canonical CRC of a compressed payload: units, gaps, and only the
    VALID outlier prefix (``pos >= 0``).

    The outlier side list is padded to a power-of-two length, but that
    width is a storage detail, not content: different producers (host vs
    device encode backends, archive round-trips, re-padded copies) may
    materialize different pad widths for the same logical payload.  Hashing
    the valid prefix keeps the digest -- and therefore every plan-cache key
    -- identical across all of them.  (Blob *integrity* CRCs, e.g.
    ``store.ChunkRecord.crc32``, still cover the stored padded bytes.)
    """
    pos = np.asarray(outlier_pos, np.int32)
    val = np.asarray(outlier_val, np.int32)
    n = int((pos >= 0).sum())
    return crc32_arrays(np.asarray(units, np.uint32),
                        np.asarray(gaps, np.uint8), pos[:n], val[:n])


def codebook_digest(enc_code, enc_len, max_len: int) -> str:
    """Content digest of a codebook (the dedup + LUT-cache key).

    The encoder tables fully determine the canonical decode LUT, so hashing
    (enc_code, enc_len, max_len) is sufficient.
    """
    h = hashlib.sha1()
    h.update(np.asarray(enc_code, np.uint32).tobytes())
    h.update(np.asarray(enc_len, np.uint8).tobytes())
    h.update(struct.pack("<I", max_len))
    return h.hexdigest()


def chunk_digest(payload_crc: int, total_bits: int, n_symbols: int,
                 subseqs_per_seq: int, codebook_digest_: str) -> str:
    """Stable identity of a chunk's *decode problem* (the plan-cache key).

    Two chunks with the same payload bytes, framing, and codebook decode
    through identical phase 1-3 plans, so the cache key hashes exactly that.
    """
    h = hashlib.sha1()
    h.update(struct.pack("<IqqI", payload_crc & 0xFFFFFFFF, total_bits,
                         n_symbols, subseqs_per_seq))
    h.update(codebook_digest_.encode())
    return h.hexdigest()


def compressed_digest(c) -> str:
    """Digest of an in-memory ``Compressed`` -- identical to the digest the
    archive writer records for the same payload, so plans cached by a store
    read are hits for a direct ``Codec.decompress`` and vice versa.

    Memoized on the object (and its codebook): the CRC pass over the
    payload runs once per tensor, not once per decode.
    """
    d = getattr(c, "_digest", None)
    if d is not None:
        return d
    book = c.codebook
    cbd = getattr(book, "_digest", None)
    if cbd is None:
        cbd = codebook_digest(book.enc_code, book.enc_len, int(book.max_len))
        try:
            # Codebook is a frozen dataclass; the digest memo is not part of
            # its value, so bypass the frozen guard.
            object.__setattr__(book, "_digest", cbd)
        except AttributeError:
            pass
    crc = payload_crc(c.stream.units, c.stream.gaps,
                      c.outlier_pos, c.outlier_val)
    d = chunk_digest(crc, int(c.stream.total_bits), int(c.stream.n_symbols),
                     int(c.stream.subseqs_per_seq), cbd)
    try:
        c._digest = d
    except AttributeError:
        pass
    return d


class PlanCache:
    def __init__(self, max_plans: int = 4096):
        self.max_plans = max_plans
        self._books: dict = {}
        self._plans: collections.OrderedDict = collections.OrderedDict()
        self._inflight: dict = {}
        self._lock = threading.Lock()
        self.stats = {"plan_hits": 0, "plan_misses": 0,
                      "lut_hits": 0, "lut_misses": 0}

    # -- codebooks / LUTs ---------------------------------------------------

    def get_codebook(self, digest: str, build_fn):
        """Return the cached ``Codebook`` for ``digest``, building via
        ``build_fn()`` on first use."""
        with self._lock:
            book = self._books.get(digest)
            if book is not None:
                self.stats["lut_hits"] += 1
                return book
            self.stats["lut_misses"] += 1
        book = build_fn()
        with self._lock:
            return self._books.setdefault(digest, book)

    # -- plans --------------------------------------------------------------

    def get_plan(self, key):
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                self.stats["plan_hits"] += 1
            else:
                self.stats["plan_misses"] += 1
            return plan

    def put_plan(self, key, plan):
        with self._lock:
            self._plans[key] = plan
            self._plans.move_to_end(key)
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)

    def get_or_build_plan(self, key, build_fn):
        """Single-flight plan resolution: concurrent misses on the same key
        build ONCE (one ``plan_builds`` tick), everyone else blocks on the
        winner's result.  This keeps the build counters deterministic when
        N serving threads decode the same hot prefix through one shared
        codec -- without it, simultaneous misses each rebuild the plan and
        the "decoded once" invariant is unverifiable.

        Build failures propagate to every waiter and are not cached, so a
        transient error does not poison the key.
        """
        import concurrent.futures as futures

        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                self.stats["plan_hits"] += 1
                return plan
            fut = self._inflight.get(key)
            owner = fut is None
            if owner:
                fut = futures.Future()
                self._inflight[key] = fut
                self.stats["plan_misses"] += 1
            else:
                # Another thread is building this exact plan; its result
                # serves us too (a hit: the plan is not rebuilt).
                self.stats["plan_hits"] += 1
        if not owner:
            return fut.result()
        try:
            plan = build_fn()
        except BaseException as e:
            with self._lock:
                self._inflight.pop(key, None)
            fut.set_exception(e)
            raise
        self.put_plan(key, plan)
        with self._lock:
            self._inflight.pop(key, None)
        fut.set_result(plan)
        return plan

    def clear(self):
        with self._lock:
            self._books.clear()
            self._plans.clear()

    def reset_stats(self):
        with self._lock:
            for k in self.stats:
                self.stats[k] = 0

    def __len__(self):
        return len(self._plans)


#: Process-wide default used by the default ``Codec`` (and therefore by
#: ``Archive`` / ``KVPager`` unless given their own codec or cache).
DEFAULT_PLAN_CACHE = PlanCache()
