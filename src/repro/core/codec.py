"""Codec sessions: one configured object for compress / decompress.

The paper's decoder choices -- gap-array vs. self-sync sync discovery,
tile/padded/tuned decode-write, the online per-CR-class tuner -- are
*policy*, not per-call detail.  ``CodecConfig`` freezes that policy (plus
the quantizer settings: error bound, bound mode, radius) into one hashable
value, and ``Codec`` binds it to the two stateful resources every decode
needs:

* the **backend handle** (``pipeline.get_backend``) with its dispatch /
  plan-build counters, and
* a digest-keyed **PlanCache** so phase 1-3 sync/count/prefix-sum plans are
  built once per distinct payload, no matter which consumer decodes it
  (archive reads, checkpoint restore, KV page-ins, direct library calls all
  share the same ``(chunk digest, method, t_high)`` key space).

Consumers (``repro.store``, ``checkpoint.CheckpointManager``,
``models.kvcache``, ``launch/serve``, the benchmarks) accept a Codec
instead of growing kwarg soup.  The module-level ``compress`` /
``decompress`` / ``decompress_batch`` functions remain as thin shims over a
default Codec; the legacy ``use_tiles`` / ``use_kernels`` / ``tuned`` flag
triple is gone from every signature and raises a ``TypeError`` pointing at
``CodecConfig``.

    codec = Codec(CodecConfig(eb=1e-4, backend="pallas", strategy="tuned"))
    c = codec.compress(x)
    xhat = codec.decompress(c)                  # plan cached by digest
    shards = codec.compress_tree({"w": w, "b": b})
    restored = codec.decompress_tree(shards)    # one dispatch per CR class
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import numpy as np

from repro.core.cache import DEFAULT_PLAN_CACHE, PlanCache, compressed_digest
from repro.core.huffman import codebook as cb
from repro.core.huffman import encode as he
from repro.core.huffman import pipeline as hp
from repro.core.sz import compressor, lorenzo
from repro.core.sz.compressor import Compressed
from repro.runtime import fault_tolerance as ft

VALID_MODES = ("rel", "abs")
VALID_METHODS = ("gap", "selfsync", "naive_ref")
VALID_STRATEGIES = hp.VALID_STRATEGIES

#: The one home of the default error bound / bound mode (the scattered
#: per-consumer ``eb=1e-3`` / ``mode="rel"`` literals collapse onto this).
DEFAULT_EB = compressor.DEFAULT_EB


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Frozen compression + decode policy; hashable, validates on build.

    Quantizer / encoder side:
      eb               error bound (relative to the value range for
                       ``mode="rel"``, absolute for ``mode="abs"``)
      mode             "rel" | "abs"
      radius           Lorenzo quantization radius (2*radius bins)
      max_len          codeword length cap (decode-LUT size is 2**max_len)
      subseqs_per_seq  encoder framing (128-bit subsequences per sequence)
      encode_backend   a ``pipeline.available_encode_backends()`` name:
                       "ref" is the host write path (f64 prequantization +
                       numpy histogram + searchsorted bit-pack); "jnp" /
                       "pallas" / "pallas-compiled" run quantize ->
                       outlier gather -> histogram -> bit-pack emit on
                       device, transferring only the 2*radius-entry
                       histogram to host for codebook construction.  The
                       emitted ``Compressed`` payload is layout-identical
                       across backends (decode never knows who wrote it);
                       inputs a device backend cannot serve (non-float32)
                       fall back to "ref" and count
                       ``stats["encode_fallbacks"]``.

    Decoder side (paper policy knobs):
      method           "gap" (gap-array sync) | "selfsync" | "naive_ref"
      backend          a ``pipeline.available_backends()`` name
      strategy         "tuned" (per-CR-class tiles, Alg. 2) | "tile"
                       (fixed tiles, Alg. 1) | "padded" (baseline layout)
      t_high           highest non-overflow CR class of the tuner
      tile_syms        tile size for the fixed-"tile" strategy
      fused            decode→dequantize→reconstruct in ONE dispatch: phase
                       4 emits reconstructed values directly, never writing
                       the uint16 quant-code array to HBM.  Bit-exact with
                       the two-pass path.  Serves 1-D/2-D/3-D tensors
                       (unit axes squeezed) in float32 / bfloat16 / float16
                       (``compressor.FUSED_DTYPES``; low-precision outputs
                       compute in f32 with one final cast).  Decodes it
                       cannot serve (>3-D tensors, other dtypes, rows over
                       ``compressor.FUSED_MAX_COLS``, 3-D planes over
                       ``compressor.FUSED_MAX_PLANE``, the "tuned"
                       strategy, "naive_ref", or a backend without fused
                       ops) automatically fall back to two-pass and count
                       ``stats["fused_fallbacks"]`` once per tensor.

    Session side:
      plan_cache_size  LRU bound of the Codec's digest-keyed plan cache

    Recovery side (what consumers do when a read fails; see
    ``runtime/fault_tolerance.py:RecoveryPolicy`` and docs/robustness.md):
      recovery         "raise" (default) | "skip" | "zero_fill" -- applied
                       by ``Archive.iter_decode``, ``CheckpointManager.
                       restore`` (salvage mode) and ``KVPager.page_in`` to
                       persistent corruption; per-call ``policy=`` overrides
                       win over this default.
      io_retries       transient-IO retry count for store reads (``OSError``
                       only; corruption is never retried)
      io_backoff       initial backoff seconds between retries (doubles)
    """

    eb: float = DEFAULT_EB
    mode: str = "rel"
    radius: int = lorenzo.DEFAULT_RADIUS
    max_len: int = cb.DEFAULT_MAX_LEN
    subseqs_per_seq: int = he.DEFAULT_SUBSEQS_PER_SEQ
    encode_backend: str = "ref"
    method: str = "gap"
    backend: str = "ref"
    strategy: str = "tile"
    t_high: int = hp.T_HIGH_DEFAULT
    tile_syms: int = hp.DEFAULT_TILE_SYMS
    fused: bool = False
    plan_cache_size: int = 4096
    recovery: str = "raise"
    io_retries: int = 2
    io_backoff: float = 0.05

    def __post_init__(self):
        if not (self.eb > 0):
            raise ValueError(f"eb must be positive, got {self.eb!r}")
        if self.mode not in VALID_MODES:
            raise ValueError(
                f"unknown mode {self.mode!r}; valid modes: {VALID_MODES}")
        if self.method not in VALID_METHODS:
            raise ValueError(f"unknown method {self.method!r}; valid "
                             f"methods: {VALID_METHODS}")
        if self.strategy not in VALID_STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; valid "
                             f"strategies: {VALID_STRATEGIES}")
        if self.backend not in hp.available_backends():
            raise ValueError(f"unknown backend {self.backend!r}; available: "
                             f"{hp.available_backends()}")
        if self.encode_backend not in hp.available_encode_backends():
            raise ValueError(
                f"unknown encode_backend {self.encode_backend!r}; "
                f"available: {hp.available_encode_backends()}")
        if self.t_high < 1:
            raise ValueError(f"t_high must be >= 1, got {self.t_high}")
        if self.radius < 2:
            raise ValueError(f"radius must be >= 2, got {self.radius}")
        if not (1 <= self.max_len <= 24):
            raise ValueError(f"max_len must be in [1, 24], got {self.max_len}")
        if self.tile_syms < 1:
            raise ValueError(f"tile_syms must be >= 1, got {self.tile_syms}")
        if self.subseqs_per_seq < 1:
            raise ValueError("subseqs_per_seq must be >= 1, got "
                             f"{self.subseqs_per_seq}")
        if not isinstance(self.fused, bool):
            raise ValueError(f"fused must be a bool, got {self.fused!r}")
        if self.plan_cache_size < 0:
            raise ValueError("plan_cache_size must be >= 0, got "
                             f"{self.plan_cache_size}")
        if self.recovery not in ft.VALID_RECOVERY:
            raise ValueError(f"unknown recovery {self.recovery!r}; valid "
                             f"policies: {ft.VALID_RECOVERY}")
        if self.io_retries < 0:
            raise ValueError(f"io_retries must be >= 0, got "
                             f"{self.io_retries}")
        if self.io_backoff < 0:
            raise ValueError(f"io_backoff must be >= 0, got "
                             f"{self.io_backoff}")

    def replace(self, **changes) -> "CodecConfig":
        return dataclasses.replace(self, **changes)


class Codec:
    """A configured compression/decompression session.

    Holds a ``CodecConfig``, the resolved backend handle (whose ``stats``
    count decode-write dispatches and plan builds), and a digest-keyed
    ``PlanCache``.  All the framework surfaces (store archives, checkpoint
    manager, KV pager, serving) accept one of these, so plan reuse and
    policy travel together instead of being re-decided at every call site.
    """

    def __init__(self, config: "CodecConfig | None" = None, *,
                 plan_cache: "PlanCache | None" = None):
        self.config = config if config is not None else CodecConfig()
        self.backend = hp.get_backend(self.config.backend)
        self.encode_backend = hp.get_encode_backend(
            self.config.encode_backend)
        self.plan_cache = (plan_cache if plan_cache is not None
                           else PlanCache(self.config.plan_cache_size))

    def __repr__(self):
        c = self.config
        return (f"Codec(eb={c.eb:g}, mode={c.mode!r}, method={c.method!r}, "
                f"backend={c.backend!r}, strategy={c.strategy!r})")

    @property
    def stats(self) -> dict:
        """Merged backend dispatch counters + plan-cache hit counters.

        Backend handles are process-wide singletons per name, so the
        dispatch/plan-build counters are shared by every codec on the same
        backend (and ``reset_stats`` zeroes them for all of them); the
        plan-cache counters are per-codec unless a cache was injected.
        The encode backend's write-path counters (``encode_dispatches``,
        ``encode_fallbacks``, ``encoder_plan_builds``) merge in under their
        own keys -- disjoint from the decode counters by construction.
        """
        return {**self.backend.stats, **self.encode_backend.stats,
                **self.plan_cache.stats}

    def reset_stats(self):
        self.backend.reset_stats()
        self.encode_backend.reset_stats()
        self.plan_cache.reset_stats()

    def recovery_policy(self, policy=None) -> ft.RecoveryPolicy:
        """This codec's ``RecoveryPolicy``; ``policy`` (a string or a
        ``RecoveryPolicy``) overrides the config's ``recovery`` default."""
        return ft.RecoveryPolicy.resolve(policy, self.config)

    # -- single tensors ------------------------------------------------------

    def compress(self, x) -> Compressed:
        c = self.config
        return compressor.compress(x, eb=c.eb, mode=c.mode, radius=c.radius,
                                   max_len=c.max_len,
                                   subseqs_per_seq=c.subseqs_per_seq,
                                   encode_backend=self.encode_backend)

    def build_plan(self, stream, codebook) -> hp.DecoderPlan:
        """Phase 1-3 plan under this codec's (method, backend, t_high)."""
        c = self.config
        return hp.build_plan(stream, codebook, method=c.method,
                             backend=self.backend, t_high=c.t_high)

    def plan_for(self, compressed: Compressed) -> hp.DecoderPlan:
        """Cached ``DecoderPlan`` for one tensor, keyed by content digest.

        The key space is shared with the archive reader: a plan built while
        streaming a ``.szt`` chunk is a hit here and vice versa.  Plan
        resolution is single-flight (``PlanCache.get_or_build_plan``): N
        threads missing on the same payload concurrently build it once.
        """
        c = self.config
        key = (compressed_digest(compressed), c.method, c.t_high)
        return self.plan_cache.get_or_build_plan(
            key, lambda: self.build_plan(compressed.stream,
                                         compressed.codebook))

    def decompress(self, compressed: Compressed, *, plan=None):
        """Decompress one tensor under the codec's policy.

        The phase 1-3 plan is fetched from / inserted into the plan cache
        by content digest; with ``config.fused`` the decode runs the fused
        decode→dequantize→reconstruct dispatch (falling back to two-pass,
        counted in ``stats["fused_fallbacks"]``, when it cannot serve the
        tensor).
        """
        c = self.config
        if plan is None and c.method != "naive_ref":
            plan = self.plan_for(compressed)
        return compressor.decompress(compressed, method=c.method,
                                     tile_syms=c.tile_syms,
                                     backend=self.backend,
                                     strategy=c.strategy, t_high=c.t_high,
                                     plan=plan, fused=c.fused)

    def decompress_batch(self, cs, *, plans=None) -> list:
        """Decompress many tensors: one decode-write dispatch per CR class
        across ALL of them, phase 1-3 plans served from the cache.  With
        ``config.fused``, eligible tensors instead decode through the fused
        per-tensor dispatch (see ``compressor.decompress_batch``)."""
        cs = list(cs)
        if not cs:
            return []
        c = self.config
        if c.method == "naive_ref":
            return [self.decompress(x) for x in cs]
        if plans is None:
            plans = [self.plan_for(x) for x in cs]
        return compressor.decompress_batch(cs, method=c.method,
                                           backend=self.backend,
                                           strategy=c.strategy,
                                           t_high=c.t_high, plans=plans,
                                           fused=c.fused)

    def decode(self, stream, codebook, n_out: int, *, plan=None,
               early_exit: bool = True):
        """Decode a raw encoded stream to quant codes (no dequantization).

        The benchmark harness rides on this: every paper decoder variant is
        one ``CodecConfig`` (method x strategy x backend) driving the same
        entry point.
        """
        c = self.config
        return hp.decode(stream, codebook, n_out, plan=plan, method=c.method,
                         backend=self.backend, strategy=c.strategy,
                         tile_syms=c.tile_syms, t_high=c.t_high,
                         early_exit=early_exit)

    # -- pytrees -------------------------------------------------------------

    def compress_tree(self, tree, *, min_size: int = 1, predicate=None):
        """Compress every compressible leaf of a pytree, in place of it.

        A leaf is compressed when ``predicate(leaf)`` is true (default:
        float32 / bfloat16 / float16 -- the dtypes checkpoints and KV
        caches actually hold, ``compressor.FUSED_DTYPES`` -- with at least
        ``min_size`` elements); everything else passes through untouched,
        so checkpoint shards and KV blocks can hand whole trees over
        instead of hand-rolling dict loops.
        """
        if predicate is None:
            def predicate(leaf):
                arr = np.asarray(leaf)
                return (arr.dtype.name in compressor.FUSED_DTYPES
                        and arr.size >= min_size)
        return jax.tree.map(
            lambda leaf: self.compress(leaf) if predicate(leaf) else leaf,
            tree)

    def decompress_tree(self, tree, *, shardings=None):
        """Inverse of ``compress_tree``: every ``Compressed`` leaf decodes
        through ONE class-batched ``decompress_batch`` call; other leaves
        pass through untouched.

        ``shardings`` (optional) is a pytree matching ``tree`` whose leaves
        are ``jax.sharding.Sharding`` or ``None``: decoded (and
        pass-through) leaves with a sharding are placed into it with
        ``jax.device_put``, so a restored tree lands directly in its target
        layout instead of on the default device.
        """
        leaves, treedef = jax.tree_util.tree_flatten(
            tree, is_leaf=lambda x: isinstance(x, Compressed))
        shard_leaves = None
        if shardings is not None:
            shard_leaves, sdef = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: x is None or
                isinstance(x, jax.sharding.Sharding))
            if len(shard_leaves) != len(leaves):
                raise ValueError(
                    f"shardings tree has {len(shard_leaves)} leaves but the "
                    f"compressed tree has {len(leaves)}")
        idx = [i for i, leaf in enumerate(leaves)
               if isinstance(leaf, Compressed)]
        outs = self.decompress_batch([leaves[i] for i in idx])
        for i, out in zip(idx, outs):
            leaves[i] = out
        if shard_leaves is not None:
            leaves = [jax.device_put(leaf, s) if s is not None else leaf
                      for leaf, s in zip(leaves, shard_leaves)]
        return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Default codec + module-level shims
# ---------------------------------------------------------------------------

_DEFAULT_CODEC: "Codec | None" = None
_SHIM_CODECS: dict = {}
_SHIM_LOCK = threading.Lock()


def default_codec() -> Codec:
    """The process-wide default ``Codec`` (default config, shared
    ``DEFAULT_PLAN_CACHE``) used by the module-level shims and by consumers
    constructed without an explicit codec."""
    global _DEFAULT_CODEC
    if _DEFAULT_CODEC is None:
        _DEFAULT_CODEC = Codec(CodecConfig(), plan_cache=DEFAULT_PLAN_CACHE)
    return _DEFAULT_CODEC


def _codec_for(config: CodecConfig) -> Codec:
    """Memoized per-config codecs for the shims; all share the default plan
    cache so kwarg-style callers still get digest-keyed plan reuse."""
    if config == default_codec().config:
        return default_codec()
    with _SHIM_LOCK:
        codec = _SHIM_CODECS.get(config)
        if codec is None:
            codec = Codec(config, plan_cache=DEFAULT_PLAN_CACHE)
            if len(_SHIM_CODECS) >= 64:   # kwarg soup bound, not a cache
                _SHIM_CODECS.clear()
            _SHIM_CODECS[config] = codec
        return codec


_REMOVED_FLAGS = ("use_tiles", "use_kernels", "tuned")


def _reject_removed(fn_name: str, kwargs: dict):
    bad = sorted(set(kwargs) & set(_REMOVED_FLAGS))
    if bad:
        raise TypeError(
            f"{fn_name}() no longer accepts {', '.join(bad)}; configure a "
            f"repro.core.Codec instead -- CodecConfig(backend='pallas'|'ref')"
            f" replaces use_kernels, CodecConfig(strategy='tuned'|'tile'|"
            f"'padded') replaces tuned/use_tiles (see docs/api.md)")
    if kwargs:
        raise TypeError(f"{fn_name}() got unexpected keyword arguments "
                        f"{sorted(kwargs)}")


def _replace_some(config: CodecConfig, **overrides) -> CodecConfig:
    changes = {k: v for k, v in overrides.items() if v is not None}
    return config.replace(**changes) if changes else config


def compress(x, eb: "float | None" = None, mode: "str | None" = None,
             radius: "int | None" = None, max_len: "int | None" = None,
             subseqs_per_seq: "int | None" = None,
             encode_backend: "str | None" = None, **removed) -> Compressed:
    """Compress a float tensor (shim over a default ``Codec``).

    mode="rel": bound is ``eb * (max(x) - min(x))`` (the paper's setting,
    "relative error bound 1e-3"); mode="abs": bound is ``eb`` directly.
    Prefer holding a ``Codec`` when compressing more than once.
    """
    _reject_removed("compress", removed)
    cfg = _replace_some(default_codec().config, eb=eb, mode=mode,
                        radius=radius, max_len=max_len,
                        subseqs_per_seq=subseqs_per_seq,
                        encode_backend=encode_backend)
    return _codec_for(cfg).compress(x)


def decompress(c: Compressed, method: "str | None" = None,
               tile_syms: "int | None" = None, *,
               backend: "str | None" = None, strategy: "str | None" = None,
               t_high: "int | None" = None, fused: "bool | None" = None,
               plan=None, **removed):
    """Decompress one tensor (shim over a default ``Codec``).

    The legacy ``use_tiles`` / ``use_kernels`` / ``tuned`` flags are gone;
    they raise ``TypeError`` pointing at ``CodecConfig``.
    """
    _reject_removed("decompress", removed)
    cfg = _replace_some(default_codec().config, method=method,
                        tile_syms=tile_syms, backend=backend,
                        strategy=strategy, t_high=t_high, fused=fused)
    return _codec_for(cfg).decompress(c, plan=plan)


def decompress_batch(cs, method: "str | None" = None, *,
                     backend: "str | None" = None,
                     t_high: "int | None" = None, fused: "bool | None" = None,
                     plans=None, **removed) -> list:
    """Decompress many tensors with class-batched decode dispatch (shim
    over a default ``Codec``); see ``Codec.decompress_batch``."""
    _reject_removed("decompress_batch", removed)
    cfg = _replace_some(default_codec().config, method=method,
                        backend=backend, t_high=t_high, fused=fused)
    return _codec_for(cfg).decompress_batch(cs, plans=plans)
