"""Online buffer-size tuning (paper Alg. 2, adapted to VMEM tiles).

The paper tunes the *shared-memory buffer size* of the decode-write kernel
per compression-ratio class: sequences are CLASSIFY'd by their compression
ratio, HISTOGRAM'd, key-value SORT'd, and each class is decoded by a kernel
instance whose buffer is sized for that class.  Too small a buffer wastes
parallelism; too large reduces occupancy (Fig. 3: up to 40% penalty).

TPU adaptation (DESIGN.md §3): the tunable is the output-tile size
``tile_syms`` of the tile-centric decode kernel.  The trade-off it controls:

  * larger tiles  -> fewer tile-boundary subsequences decoded twice
                     (redundant decode work ~ ss_max/tile ~ 1/9 + O(1/n)),
                     but a larger VMEM staging buffer + larger (ss_max, 128)
                     decode scratch -> less room for double buffering and,
                     past the VMEM budget, compile failure (the hard analogue
                     of an occupancy cliff);
  * smaller tiles -> for *low*-CR sequences most of the statically provisioned
                     ``ss_max`` lanes are idle (a tile covers many more
                     subsequences than provisioned -- wait, fewer symbols per
                     subsequence means MORE subsequences per tile), so ss_max
                     must be provisioned for CR=min -> the per-class split
                     lets high-CR classes run with small ss_max per tile.

The per-class dispatch mirrors the paper exactly: class c in {1..T_high}
covers CR in (c-1, c]; class T_high+1 covers (T_high, 16].
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman.bits import SUBSEQ_BITS
from repro.core.huffman import decode as hd
from repro.core.huffman.encode import EncodedStream

T_HIGH_DEFAULT = 8          # paper's V100 value; VMEM budget gives the same
OVERFLOW_TILE = 3584        # paper: optimal buffer for CR > T_high on V100
SYMBOL_BYTES = 2


def sequence_ratios(seq_counts: jnp.ndarray, subseqs_per_seq: int):
    """Per-sequence compression ratio: decoded bytes / encoded bytes."""
    enc_bytes = subseqs_per_seq * SUBSEQ_BITS // 8
    return seq_counts.astype(jnp.float32) * SYMBOL_BYTES / enc_bytes


def classify(ratios: jnp.ndarray, t_high: int = T_HIGH_DEFAULT):
    """CLASSIFYCR: CR in (c-1, c] -> class c; CR > t_high -> t_high + 1."""
    cls = jnp.ceil(ratios).astype(jnp.int32)
    return jnp.clip(cls, 1, t_high + 1)


def class_histogram(classes: jnp.ndarray, t_high: int = T_HIGH_DEFAULT):
    """ParHISTOGRAM (jnp fallback; the Pallas kernel lives in repro.kernels)."""
    return jnp.bincount(classes, length=t_high + 2)


def sort_by_class(classes: jnp.ndarray):
    """ParKeyValueSort: stable key-value sort of sequence ids by class."""
    idx = jnp.arange(classes.shape[0], dtype=jnp.int32)
    keys, vals = jax.lax.sort_key_val(classes, idx, is_stable=True)
    return keys, vals


def tile_for_class(c: int, t_high: int = T_HIGH_DEFAULT) -> int:
    """Buffer (tile) size for a class: 1024 symbols per CR unit, as in the
    paper ("sequences in the (3,4] group ... buffer of length 4096"), with
    the overflow class pinned at OVERFLOW_TILE."""
    if c > t_high:
        return OVERFLOW_TILE
    return 1024 * max(c, 1)


@dataclasses.dataclass
class TuningPlan:
    """Host-side dispatch plan (per-class sequence index lists)."""

    t_high: int
    classes: np.ndarray          # int32[n_seq]
    seq_order: np.ndarray        # int32[n_seq] sequence ids sorted by class
    class_start: np.ndarray      # int32[t_high+3] prefix offsets into seq_order
    tile_syms: dict              # class -> tile size


def make_plan(stream: EncodedStream, seq_counts, subseqs_per_seq: int,
              t_high: int = T_HIGH_DEFAULT) -> TuningPlan:
    ratios = sequence_ratios(jnp.asarray(seq_counts), subseqs_per_seq)
    classes = classify(ratios, t_high)
    hist = class_histogram(classes, t_high)
    keys, order = sort_by_class(classes)
    class_start = np.zeros(t_high + 3, np.int32)
    class_start[1:] = np.cumsum(np.asarray(hist))
    return TuningPlan(
        t_high=t_high,
        classes=np.asarray(classes),
        seq_order=np.asarray(order),
        class_start=class_start,
        tile_syms={c: tile_for_class(c, t_high) for c in range(1, t_high + 2)},
    )


def _pad_pow2(n: int, lo: int = 8) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


def decode_tuned(stream: EncodedStream, dec_sym, dec_len, max_len: int,
                 n_out: int, start_bits, counts,
                 t_high: int = T_HIGH_DEFAULT,
                 decode_tiles_fn=None):
    """ShmemOptDecodeWrite: per-class tile decode with tuned buffer sizes.

    ``start_bits``/``counts`` come from the preceding phase (sync discovery
    or gap-based count decode).  ``decode_tiles_fn`` defaults to the jnp
    reference ``decode_write_tiles``; the Pallas ops layer passes its kernel.
    Returns the decoded symbols in original order.
    """
    if decode_tiles_fn is None:
        decode_tiles_fn = hd.decode_write_tiles

    sps = stream.subseqs_per_seq
    n_seq = stream.n_seq
    counts = jnp.asarray(counts)
    start_bits = jnp.asarray(start_bits)
    seq_counts = counts.reshape(n_seq, sps).sum(axis=1, dtype=jnp.int32)
    plan = make_plan(stream, seq_counts, sps, t_high)

    # Global output offset of every sequence (original order).
    seq_out_start = np.zeros(n_seq + 1, np.int64)
    seq_out_start[1:] = np.cumsum(np.asarray(seq_counts))

    out = jnp.zeros((n_out,), jnp.uint16)
    seq_counts_np = np.asarray(seq_counts)
    counts_2d = counts.reshape(n_seq, sps)
    starts_2d = start_bits.reshape(n_seq, sps)
    n_subseq = n_seq * sps
    boundaries = (jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS)
    ends_2d = (boundaries + SUBSEQ_BITS).reshape(n_seq, sps)

    for c in range(1, t_high + 2):
        lo, hi = int(plan.class_start[c]), int(plan.class_start[c + 1])
        if hi == lo:
            continue
        seq_ids = plan.seq_order[lo:hi]
        tile = plan.tile_syms[c]
        # Pad the class to a power-of-two sequence count (bounds jit cache).
        n_pad = _pad_pow2(len(seq_ids))
        ids_pad = np.zeros(n_pad, np.int32)
        ids_pad[: len(seq_ids)] = seq_ids
        valid = np.zeros(n_pad, bool)
        valid[: len(seq_ids)] = True
        ids_j = jnp.asarray(ids_pad)

        g_starts = starts_2d[ids_j].reshape(-1)
        g_ends = ends_2d[ids_j].reshape(-1)
        g_counts = jnp.where(jnp.asarray(valid)[:, None],
                             counts_2d[ids_j], 0).reshape(-1)
        g_offsets = hd.output_offsets(g_counts)
        class_n = int(np.sum(seq_counts_np[seq_ids]))
        class_n_pad = _pad_pow2(max(class_n, 1))
        ss_max = tile // ((SUBSEQ_BITS - max_len) // max_len + 1) + 2
        class_out = decode_tiles_fn(
            jnp.asarray(stream.units), dec_sym, dec_len, g_starts, g_ends,
            g_offsets, stream.total_bits, max_len, class_n_pad, tile, ss_max)

        # Scatter class-local output back to global positions.
        local_seq_start = np.zeros(len(seq_ids) + 1, np.int64)
        local_seq_start[1:] = np.cumsum(seq_counts_np[seq_ids])
        pos_global = np.concatenate([
            np.arange(seq_out_start[s], seq_out_start[s] + seq_counts_np[s],
                      dtype=np.int64)
            for s in seq_ids
        ]) if class_n else np.zeros(0, np.int64)
        out = out.at[jnp.asarray(pos_global)].set(class_out[:class_n])

    return out
