"""DEPRECATED shim: online buffer-size tuning moved into the pipeline layer.

The paper's Alg. 2 (CLASSIFY / HISTOGRAM / SORT / per-class decode dispatch)
now lives in ``repro.core.huffman.pipeline``: plan construction in
``build_plan`` / ``make_plan``, per-class execution in
``decode(strategy="tuned")`` and the batched ``decode_batch``.  This module
re-exports the classification primitives and keeps the pre-pipeline
``decode_tuned`` entry point for existing callers (benchmarks, older
notebooks).  New code should use ``pipeline.decode``.
"""

from __future__ import annotations

from repro.core.huffman.pipeline import (  # noqa: F401  (public re-exports)
    OVERFLOW_TILE,
    SYMBOL_BYTES,
    T_HIGH_DEFAULT,
    ClassPlan as TuningPlan,
    class_histogram,
    classify,
    execute_tuned,
    make_plan,
    sequence_ratios,
    sort_by_class,
    tile_for_class,
)


def decode_tuned(stream, dec_sym, dec_len, max_len: int, n_out: int,
                 start_bits, counts, t_high: int = T_HIGH_DEFAULT,
                 decode_tiles_fn=None):
    """ShmemOptDecodeWrite: per-class tile decode with tuned buffer sizes.

    DEPRECATED: thin wrapper over ``pipeline.execute_tuned`` (use
    ``pipeline.decode(..., strategy="tuned")`` for full-pipeline decodes).
    ``decode_tiles_fn`` defaults to the jnp reference ``decode_write_tiles``;
    the Pallas ops layer passes its kernel.
    """
    return execute_tuned(stream, dec_sym, dec_len, max_len, n_out,
                         start_bits, counts, t_high=t_high,
                         tiles_fn=decode_tiles_fn)
