from repro.core.huffman import bits, codebook, decode, encode  # noqa: F401
from repro.core.huffman.codebook import Codebook, build_codebook  # noqa: F401
from repro.core.huffman.encode import EncodedStream  # noqa: F401
