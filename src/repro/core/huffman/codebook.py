"""Canonical, length-limited Huffman codebooks.

cuSZ builds its codebook on the GPU (Tian et al. 2021); codebook construction
is O(K log K) for K symbols (K = 1024 quantization bins by default) and is a
negligible fraction of (de)coding time, so we build it host-side in numpy and
ship the resulting lookup tables to the device as plain arrays.

Design decisions (see DESIGN.md §9):
  * Codes are *canonical*: sorted by (length, symbol), assigned sequentially.
    Canonical codes admit compact decode tables and make encode/decode
    round-trips reproducible bit-for-bit.
  * Codes are *length-limited* to ``max_len`` (default 12) via the
    package-merge algorithm [Larmore & Hirschberg 1990].  A hard length cap
    lets the decoder use a flat ``2**max_len``-entry LUT that fits in VMEM
    (4096 x (uint16 sym + uint8 len) = 12 KiB) alongside the staging buffer,
    replacing the paper's reliance on the GPU L1/L2 caching the codebook.
  * A 128-bit subsequence therefore contains at least
    ``floor((SUBSEQ_BITS - max_len) / max_len) + 1 >= 9`` codeword starts,
    which upper-bounds the number of subsequences overlapping an output tile
    -- the static bound the Pallas decode kernels rely on.
"""

from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_MAX_LEN = 12


@dataclasses.dataclass(frozen=True)
class Codebook:
    """Encode + decode tables for one canonical Huffman code."""

    n_symbols: int
    max_len: int
    # Encoder tables, indexed by symbol.
    enc_code: np.ndarray  # uint32[K]  codeword bits, right-aligned
    enc_len: np.ndarray   # uint8[K]   codeword length; 0 => symbol unused
    # Decoder tables, indexed by the next ``max_len`` bits of the stream.
    dec_sym: np.ndarray   # uint16[2**max_len]
    dec_len: np.ndarray   # uint8[2**max_len]

    @property
    def min_len(self) -> int:
        used = self.enc_len[self.enc_len > 0]
        return int(used.min()) if used.size else 0

    def min_starts_per_subseq(self, subseq_bits: int) -> int:
        """Lower bound on codeword *starts* inside a ``subseq_bits`` window.

        Every codeword is at most ``max_len`` bits, so between two
        consecutive starts there are at most ``max_len`` bits.
        """
        return (subseq_bits - self.max_len) // self.max_len + 1


def code_lengths_package_merge(freq: np.ndarray, max_len: int) -> np.ndarray:
    """Optimal length-limited code lengths via package-merge.

    Args:
      freq: int64[K] symbol frequencies (zeros allowed -> unused symbols).
      max_len: maximum codeword length L; requires 2**L >= #nonzero symbols.

    Returns:
      uint8[K] code lengths (0 for unused symbols).
    """
    freq = np.asarray(freq, dtype=np.int64)
    k = freq.shape[0]
    sym = np.nonzero(freq > 0)[0]
    n = sym.size
    lengths = np.zeros(k, dtype=np.uint8)
    if n == 0:
        return lengths
    if n == 1:
        lengths[sym[0]] = 1
        return lengths
    if (1 << max_len) < n:
        raise ValueError(f"max_len={max_len} cannot code {n} symbols")

    # Leaf items sorted by weight.  Each item carries a per-symbol count
    # vector implicitly: we track, for every package, the multiset of leaves
    # it contains via index lists (n is small -- <= 2**16 -- so this is fine).
    order = np.argsort(freq[sym], kind="stable")
    leaves_w = freq[sym][order]            # ascending weights
    leaves_id = np.arange(n)[order]        # position in `sym`

    # packages: list of (weight, leaf_count_vector) built level by level.
    counts = np.zeros(n, dtype=np.int64)

    prev_w: list[int] = []
    prev_c: list[np.ndarray] = []
    for _level in range(max_len):
        # Merge leaves with packaged pairs from the previous level.
        cur_w: list[int] = []
        cur_c: list[np.ndarray] = []
        li, pi = 0, 0
        while li < n or pi < len(prev_w):
            take_leaf = pi >= len(prev_w) or (
                li < n and leaves_w[li] <= prev_w[pi]
            )
            if take_leaf:
                vec = np.zeros(n, dtype=np.int64)
                vec[leaves_id[li]] = 1
                cur_w.append(int(leaves_w[li]))
                cur_c.append(vec)
                li += 1
            else:
                cur_w.append(prev_w[pi])
                cur_c.append(prev_c[pi])
                pi += 1
        # Package adjacent pairs for the next level.
        nxt_w, nxt_c = [], []
        for i in range(0, len(cur_w) - 1, 2):
            nxt_w.append(cur_w[i] + cur_w[i + 1])
            nxt_c.append(cur_c[i] + cur_c[i + 1])
        prev_w, prev_c = nxt_w, nxt_c
        last_w, last_c = cur_w, cur_c

    # The optimal length-L code corresponds to the first 2n-2 items of the
    # final (unpackaged) list; a symbol's code length is the number of
    # selected items containing it.
    for i in range(2 * n - 2):
        counts += last_c[i]
    lengths[sym] = counts.astype(np.uint8)
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Assign canonical codewords given code lengths.

    Symbols are ranked by (length, symbol index); codes count upward, shifted
    left at each length increase (RFC1951-style).
    """
    lengths = np.asarray(lengths)
    k = lengths.shape[0]
    codes = np.zeros(k, dtype=np.uint32)
    used = np.nonzero(lengths > 0)[0]
    if used.size == 0:
        return codes
    order = sorted(used, key=lambda s: (lengths[s], s))
    code = 0
    prev_len = int(lengths[order[0]])
    for s in order:
        length = int(lengths[s])
        code <<= length - prev_len
        codes[s] = code
        code += 1
        prev_len = length
    return codes


def build_decode_lut(
    codes: np.ndarray, lengths: np.ndarray, max_len: int
) -> tuple[np.ndarray, np.ndarray]:
    """Flat decode LUT: index by the next ``max_len`` stream bits."""
    size = 1 << max_len
    dec_sym = np.zeros(size, dtype=np.uint16)
    dec_len = np.zeros(size, dtype=np.uint8)
    for s in np.nonzero(lengths > 0)[0]:
        length = int(lengths[s])
        lo = int(codes[s]) << (max_len - length)
        hi = lo + (1 << (max_len - length))
        dec_sym[lo:hi] = s
        dec_len[lo:hi] = length
    return dec_sym, dec_len


def build_codebook(freq: np.ndarray, max_len: int = DEFAULT_MAX_LEN) -> Codebook:
    """End-to-end: frequencies -> canonical length-limited codebook."""
    freq = np.asarray(freq, dtype=np.int64)
    lengths = code_lengths_package_merge(freq, max_len)
    codes = canonical_codes(lengths)
    dec_sym, dec_len = build_decode_lut(codes, lengths, max_len)
    return Codebook(
        n_symbols=int(freq.shape[0]),
        max_len=max_len,
        enc_code=codes,
        enc_len=lengths,
        dec_sym=dec_sym,
        dec_len=dec_len,
    )


def validate_codebook(codebook, max_len: "int | None" = None) -> list:
    """Integrity problems of a (possibly corrupt) codebook, as strings.

    Checks the canonical-code invariants that the decode LUTs rely on:
    every used codeword length lies in ``[1, max_len]``, the lengths
    satisfy the Kraft inequality (``sum 2**-len <= 1`` -- a corrupted
    length table that overfills the code space makes the LUT decode
    ambiguous garbage), and the decode tables have the ``2**max_len``
    shape with entries bounded by ``max_len``.  Returns ``[]`` for a
    healthy codebook; ``pipeline.build_plan`` raises ``DecodeGuardError``
    on anything else.  Works on ``Codebook`` and on LUT-only views
    (encoder tables are checked only when present).
    """
    problems: list = []
    L = int(max_len if max_len is not None else codebook.max_len)
    if not (1 <= L <= 24):
        return [f"max_len {L} outside [1, 24]"]

    enc_len = getattr(codebook, "enc_len", None)
    if enc_len is not None:
        lens = np.asarray(enc_len, dtype=np.int64)
        used = lens[lens > 0]
        if used.size:
            if int(used.max()) > L:
                problems.append(
                    f"codeword length {int(used.max())} exceeds "
                    f"max_len={L}")
            else:
                kraft = float(np.sum(2.0 ** -used.astype(np.float64)))
                if kraft > 1.0 + 1e-9:
                    problems.append(
                        f"Kraft inequality violated (sum 2^-len = "
                        f"{kraft:.6f} > 1)")
        elif lens.size:
            problems.append("no symbol has a nonzero codeword length")

    size = 1 << L
    for name in ("dec_sym", "dec_len"):
        tab = getattr(codebook, name, None)
        if tab is not None and tab.shape != (size,):
            problems.append(f"{name} shape {tuple(tab.shape)} != ({size},)")
    dec_len = getattr(codebook, "dec_len", None)
    if dec_len is not None and dec_len.shape == (size,) and size:
        dmax = int(np.asarray(dec_len, dtype=np.int64).max())
        if dmax > L:
            problems.append(f"decode-LUT length {dmax} exceeds max_len={L}")
    return problems


def expected_bits_per_symbol(freq: np.ndarray, lengths: np.ndarray) -> float:
    freq = np.asarray(freq, dtype=np.float64)
    total = freq.sum()
    if total == 0:
        return 0.0
    return float((freq * lengths).sum() / total)
