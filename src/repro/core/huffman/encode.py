"""Vectorized Huffman encoder (JAX) with subsequence metadata + gap arrays.

Stream format (DESIGN.md §9):
  * MSB-first bit packing into 32-bit *units* (the paper's unit).
  * A *subsequence* is ``SUBSEQ_UNITS = 4`` units = 128 bits -- the work item
    of one decoder lane.
  * A *sequence* is ``subseqs_per_seq`` subsequences -- the work item of one
    decoder grid block.  Codewords cross subsequence and sequence boundaries
    freely (no alignment padding inside the stream; only the tail is padded).

The encoder emits, alongside the packed units:
  * ``gaps``  -- uint8[n_subseq]: bit offset (< max_len) of the first codeword
    *start* at-or-after each subsequence boundary (Yamamoto et al.'s gap
    array).  Self-synchronization decoding ignores this array.
  * ``counts`` -- int32[n_subseq]: number of codewords starting inside each
    subsequence.  This is ground truth used by tests and by the *oracle*
    decode path; the real decoders recompute counts on device (phase 1 /
    the sync phase), exactly as in the paper.

Everything here is jit-able; the host wrapper in ``core/sz/compressor.py``
materializes exact (unpadded) sizes.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

SUBSEQ_UNITS = 4
UNIT_BITS = 32
SUBSEQ_BITS = SUBSEQ_UNITS * UNIT_BITS  # 128
DEFAULT_SUBSEQS_PER_SEQ = 32            # 4096-bit sequences


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EncodedStream:
    """A Huffman-coded bitstream plus decoding metadata (a pytree)."""

    units: jnp.ndarray        # uint32[n_units], padded to a whole sequence
    gaps: jnp.ndarray         # uint8[n_subseq]
    counts: jnp.ndarray       # int32[n_subseq] (ground truth / oracle only)
    seq_counts: jnp.ndarray   # int32[n_seq]    symbols per sequence
    total_bits: jnp.ndarray   # int32[] valid payload bits
    n_symbols: jnp.ndarray    # int32[] total symbols encoded
    subseqs_per_seq: int = dataclasses.field(default=DEFAULT_SUBSEQS_PER_SEQ)

    def tree_flatten(self):
        children = (self.units, self.gaps, self.counts, self.seq_counts,
                    self.total_bits, self.n_symbols)
        return children, self.subseqs_per_seq

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, subseqs_per_seq=aux)

    @property
    def n_subseq(self) -> int:
        return self.gaps.shape[0]

    @property
    def n_seq(self) -> int:
        return self.gaps.shape[0] // self.subseqs_per_seq


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def units_for_bits(total_bits: int, subseqs_per_seq: int) -> int:
    """Padded unit count for a ``total_bits`` payload (whole sequences).

    The single audited home of the padding formula: the host encoder, the
    device ``EncoderPlan`` (which sizes the padded stream from a histogram
    instead of the symbol array), and the Pallas bit-pack wrapper all call
    this so every backend emits the same layout.
    """
    n_units = _ceil_to(max(int(total_bits), 1), UNIT_BITS) // UNIT_BITS
    return _ceil_to(n_units, SUBSEQ_UNITS * subseqs_per_seq)


def pack_bits(starts, lens, codes, total_bits, n_bits_padded: int):
    """Materialize the packed uint32 units from per-symbol placement.

    ``starts`` is the exclusive prefix sum of codeword lengths, ``codes``
    the right-aligned codewords; for every output bit a ``searchsorted``
    finds the covering symbol (traced helper, shared by the jit encoder and
    the jnp oracle of the Pallas bit-pack kernel).
    """
    bit_idx = jnp.arange(n_bits_padded, dtype=jnp.int32)
    owner = jnp.searchsorted(starts, bit_idx, side="right") - 1  # [B]
    owner = jnp.clip(owner, 0, starts.shape[0] - 1)
    within = bit_idx - starts[owner]
    code = codes[owner].astype(jnp.uint32)
    length = lens[owner]
    # MSB-first: bit 0 of the codeword is its most significant bit.
    shift = jnp.maximum(length - 1 - within, 0).astype(jnp.uint32)
    bits = (code >> shift) & jnp.uint32(1)
    bits = jnp.where(bit_idx < total_bits, bits, jnp.uint32(0))

    # Pack MSB-first into uint32 units.
    weights = (jnp.uint32(1) << jnp.arange(31, -1, -1, dtype=jnp.uint32))
    return (bits.reshape(-1, UNIT_BITS) * weights[None, :]).sum(
        axis=1, dtype=jnp.uint32
    )


def stream_metadata(starts, total_bits, n_units_padded: int,
                    subseqs_per_seq: int):
    """Gap array + per-subsequence counts from codeword start positions.

    Pure metadata math (no payload access), shared by the jit encoder and
    the Pallas bit-pack wrapper so every encode backend emits bit-identical
    ``gaps`` / ``counts`` / ``seq_counts``.
    """
    n_subseq = n_units_padded // SUBSEQ_UNITS
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    # First codeword start at-or-after each boundary.
    first = jnp.searchsorted(starts, boundaries, side="left")
    first_start = jnp.where(
        first < starts.shape[0], starts[jnp.clip(first, 0, starts.shape[0] - 1)],
        total_bits,
    )
    gaps = jnp.clip(first_start - boundaries, 0, 255).astype(jnp.uint8)
    # Codeword starts inside each subsequence.
    ends = jnp.searchsorted(starts, boundaries + SUBSEQ_BITS, side="left")
    counts = (ends - first).astype(jnp.int32)
    seq_counts = counts.reshape(-1, subseqs_per_seq).sum(
        axis=1, dtype=jnp.int32
    )
    return gaps, counts, seq_counts


@partial(jax.jit, static_argnames=("n_units_padded", "subseqs_per_seq"))
def _encode_padded(
    symbols: jnp.ndarray,
    enc_code: jnp.ndarray,
    enc_len: jnp.ndarray,
    n_units_padded: int,
    subseqs_per_seq: int,
) -> EncodedStream:
    """Core vectorized encoder; ``n_units_padded`` fixed for jit."""
    symbols = symbols.astype(jnp.int32)
    lens = enc_len[symbols].astype(jnp.int32)          # [N]
    starts = jnp.cumsum(lens) - lens                   # exclusive scan [N]
    total_bits = (starts[-1] + lens[-1]).astype(jnp.int32)

    units = pack_bits(starts, lens, enc_code[symbols], total_bits,
                      n_units_padded * UNIT_BITS)
    gaps, counts, seq_counts = stream_metadata(starts, total_bits,
                                               n_units_padded,
                                               subseqs_per_seq)
    return EncodedStream(
        units=units,
        gaps=gaps,
        counts=counts,
        seq_counts=seq_counts,
        total_bits=total_bits,
        n_symbols=jnp.asarray(symbols.shape[0], jnp.int32),
        subseqs_per_seq=subseqs_per_seq,
    )


@partial(jax.jit, static_argnames=("n_units_padded", "subseqs_per_seq",
                                   "min_len"))
def _encode_gather_padded(
    symbols: jnp.ndarray,
    enc_code: jnp.ndarray,
    enc_len: jnp.ndarray,
    n_units_padded: int,
    subseqs_per_seq: int,
    min_len: int,
) -> EncodedStream:
    """Per-unit gather encoder: the Pallas bit-pack kernel's math in jnp.

    Where :func:`pack_bits` materializes every output *bit* (a
    ``searchsorted`` per bit -- O(total_bits * log n)), this walks output
    *units*: each uint32 unit gathers the <= ``32 // min_len + 2`` codewords
    that can overlap its 32-bit window (one left-crosser plus the starts
    inside it -- the same static lane budget as
    ``kernels/huffman_encode.pack_tiles``) and ORs together their hi/lo
    split contributions.  Bit-identical to ``_encode_padded`` (asserted by
    the encode parity matrix) at a fraction of the work; this is the
    "jnp" encode backend's pack, i.e. the timeable device proxy for the
    kernel.
    """
    sym = symbols.astype(jnp.int32)
    n = sym.shape[0]
    lens = enc_len[sym].astype(jnp.int32)              # [N]
    starts = jnp.cumsum(lens) - lens                   # exclusive scan [N]
    total_bits = (starts[-1] + lens[-1]).astype(jnp.int32)
    codes = enc_code[sym].astype(jnp.uint32)

    lanes = UNIT_BITS // max(min_len, 1) + 2
    base = jnp.arange(n_units_padded, dtype=jnp.int32) * UNIT_BITS
    # Last codeword starting at-or-before each unit's first bit: the only
    # candidate that can cross in from the left (codewords are contiguous).
    s0 = jnp.clip(jnp.searchsorted(starts, base, side="right") - 1, 0, n - 1)
    k = s0[:, None] + jnp.arange(lanes, dtype=jnp.int32)[None, :]
    valid = k < n
    kc = jnp.clip(k, 0, n - 1)
    st = starts[kc]
    length = jnp.where(valid, lens[kc], 0)
    code = codes[kc]

    # Unit-local placement (p may be negative for the left-crosser); the
    # codeword occupies the 64-bit window ``code << (64 - o - length)``
    # whose high word lands in unit ``u`` and low word in ``u + 1`` --
    # identical arithmetic to kernels/huffman_encode._pack_kernel.
    p = st - base[:, None]
    u = p >> 5
    o = p & 31
    shift = 64 - o - length
    hi = jnp.where(
        shift >= 32,
        code << jnp.clip(shift - 32, 0, 31).astype(jnp.uint32),
        code >> jnp.clip(32 - shift, 0, 31).astype(jnp.uint32),
    )
    lo = jnp.where(shift >= 32, jnp.uint32(0),
                   code << jnp.clip(shift, 0, 31).astype(jnp.uint32))
    active = length > 0
    contrib = (jnp.where(active & (u == 0), hi, jnp.uint32(0))
               | jnp.where(active & (u == -1), lo, jnp.uint32(0)))
    units = jax.lax.reduce(contrib, jnp.uint32(0), jax.lax.bitwise_or, (1,))

    gaps, counts, seq_counts = stream_metadata(starts, total_bits,
                                               n_units_padded,
                                               subseqs_per_seq)
    return EncodedStream(
        units=units,
        gaps=gaps,
        counts=counts,
        seq_counts=seq_counts,
        total_bits=total_bits,
        n_symbols=jnp.asarray(n, jnp.int32),
        subseqs_per_seq=subseqs_per_seq,
    )


def encode_gather(
    symbols,
    enc_code,
    enc_len,
    total_bits: int,
    subseqs_per_seq: int = DEFAULT_SUBSEQS_PER_SEQ,
    min_len: int = 1,
) -> EncodedStream:
    """Device-proxy encode: per-unit gather pack under a known bit total.

    ``total_bits`` comes from the ``EncoderPlan`` (histogram dot lengths),
    so the symbol array never has to visit the host for sizing.
    """
    if int(symbols.shape[0]) == 0:
        return empty_stream(subseqs_per_seq)
    n_units_padded = units_for_bits(total_bits, subseqs_per_seq)
    return _encode_gather_padded(jnp.asarray(symbols), jnp.asarray(enc_code),
                                 jnp.asarray(enc_len),
                                 n_units_padded=n_units_padded,
                                 subseqs_per_seq=subseqs_per_seq,
                                 min_len=int(min_len))


def empty_stream(subseqs_per_seq: int = DEFAULT_SUBSEQS_PER_SEQ
                 ) -> EncodedStream:
    """A valid zero-symbol stream (one zero-padded sequence).

    ``_encode_padded`` indexes ``starts[-1]`` and so cannot trace an empty
    symbol array; every encode entry point routes empty inputs here instead.
    """
    n_units_padded = units_for_bits(0, subseqs_per_seq)
    n_subseq = n_units_padded // SUBSEQ_UNITS
    return EncodedStream(
        units=jnp.zeros((n_units_padded,), jnp.uint32),
        gaps=jnp.zeros((n_subseq,), jnp.uint8),
        counts=jnp.zeros((n_subseq,), jnp.int32),
        seq_counts=jnp.zeros((n_subseq // subseqs_per_seq,), jnp.int32),
        total_bits=jnp.asarray(0, jnp.int32),
        n_symbols=jnp.asarray(0, jnp.int32),
        subseqs_per_seq=subseqs_per_seq,
    )


def encode(
    symbols,
    enc_code,
    enc_len,
    subseqs_per_seq: int = DEFAULT_SUBSEQS_PER_SEQ,
) -> EncodedStream:
    """Encode a symbol array.  Host wrapper: sizes the padded stream.

    The padded size is computed from an exact host-side bit count so the
    jit cache keys on (n_units_padded, subseqs_per_seq) only.
    """
    symbols_np = np.asarray(symbols)
    if symbols_np.size == 0:
        return empty_stream(subseqs_per_seq)
    enc_len_np = np.asarray(enc_len)
    total_bits = int(enc_len_np[symbols_np].astype(np.int64).sum())
    n_units_padded = units_for_bits(total_bits, subseqs_per_seq)
    return _encode_padded(
        jnp.asarray(symbols_np),
        jnp.asarray(enc_code),
        jnp.asarray(enc_len),
        n_units_padded=n_units_padded,
        subseqs_per_seq=subseqs_per_seq,
    )


def encode_chunked(
    symbols,
    enc_code,
    enc_len,
    chunk_symbols: int = 16384,
) -> dict:
    """cuSZ-style *coarse-grained* chunked encoding (the paper's baseline).

    Each fixed-size chunk of input symbols is encoded independently and
    padded to a unit boundary; the decoder runs one sequential thread per
    chunk.  The per-chunk padding is the compression-ratio cost the paper
    mentions for small chunks.
    """
    symbols = np.asarray(symbols)
    enc_code = np.asarray(enc_code, dtype=np.uint32)
    enc_len = np.asarray(enc_len, dtype=np.uint8)
    n = symbols.shape[0]
    n_chunks = (n + chunk_symbols - 1) // chunk_symbols

    unit_rows = []
    chunk_bits = np.zeros(n_chunks, dtype=np.int64)
    chunk_syms = np.zeros(n_chunks, dtype=np.int32)
    max_units = 0
    for c in range(n_chunks):
        chunk = symbols[c * chunk_symbols : (c + 1) * chunk_symbols]
        lens = enc_len[chunk].astype(np.int64)
        starts = np.cumsum(lens) - lens
        bits_total = int(lens.sum())
        n_units = max(1, (bits_total + UNIT_BITS - 1) // UNIT_BITS)
        bit_idx = np.arange(n_units * UNIT_BITS, dtype=np.int64)
        owner = np.clip(
            np.searchsorted(starts, bit_idx, side="right") - 1, 0, len(chunk) - 1
        )
        within = bit_idx - starts[owner]
        code = enc_code[chunk[owner]].astype(np.uint64)
        shift = np.maximum(lens[owner] - 1 - within, 0).astype(np.uint64)
        bits = ((code >> shift) & np.uint64(1)).astype(np.uint32)
        bits[bit_idx >= bits_total] = 0
        weights = (1 << np.arange(31, -1, -1, dtype=np.uint64)).astype(np.uint64)
        units = (bits.reshape(-1, UNIT_BITS).astype(np.uint64) * weights).sum(
            axis=1
        ).astype(np.uint32)
        unit_rows.append(units)
        chunk_bits[c] = bits_total
        chunk_syms[c] = len(chunk)
        max_units = max(max_units, n_units)

    padded = np.zeros((n_chunks, max_units), dtype=np.uint32)
    for c, row in enumerate(unit_rows):
        padded[c, : row.shape[0]] = row
    return {
        "units": jnp.asarray(padded),          # [n_chunks, max_units]
        "chunk_bits": jnp.asarray(chunk_bits),
        "chunk_syms": jnp.asarray(chunk_syms),
        "chunk_symbols": chunk_symbols,
        "n_symbols": n,
        # stored bytes: real per-chunk unit counts (unit-aligned padding),
        # matching how cuSZ accounts chunked storage.
        "stored_bytes": int(
            sum(((b + UNIT_BITS - 1) // UNIT_BITS) * 4 for b in chunk_bits)
        ),
    }
