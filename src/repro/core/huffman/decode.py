"""Parallel Huffman decoders: self-synchronization and gap-array.

This module contains the *reference* (pure-jnp, jit-able) implementations of
every decoding phase, mirroring the paper's decomposition:

  self-sync (Weissenberger & Schmidt, optimized per paper §IV-A):
    1. intra-sequence synchronization      -> `selfsync_intra`
    2. inter-sequence synchronization      -> `selfsync_inter`
    3. output-index prefix sum             -> `output_offsets`
    4. decode + write                      -> `decode_write` (VMEM-staged
                                              tile variant: `decode_write_tiles`)

  gap-array (Yamamoto et al.):
    1. count decode ("get output idx.")    -> `subseq_scan` with gap starts
    2. prefix sum                          -> `output_offsets`
    3. decode + write                      -> same as above

The Pallas kernels in ``repro.kernels`` implement the same phases with
explicit VMEM tiling; ``repro.kernels.*.ref`` delegates here so every kernel
has a single oracle.  The sequential ``decode_sequential`` is the ground-truth
oracle for everything else.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.huffman.bits import SUBSEQ_BITS, peek
from repro.core.huffman.encode import EncodedStream

# Worst-case codewords per 128-bit subsequence (min codeword length 1).
MAX_SYMS_PER_SUBSEQ = SUBSEQ_BITS


# ---------------------------------------------------------------------------
# Ground-truth sequential decoder
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_symbols", "max_len"))
def decode_sequential(units, dec_sym, dec_len, n_symbols: int, max_len: int):
    """Decode the whole stream with a single sequential scan (oracle)."""

    def step(pos, _):
        win = peek(units, pos, max_len)
        sym = dec_sym[win]
        length = dec_len[win].astype(jnp.int32)
        return pos + length, sym

    _, syms = jax.lax.scan(step, jnp.int32(0), None, length=n_symbols)
    return syms


# ---------------------------------------------------------------------------
# Subsequence window scan (the shared inner loop of every phase)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("max_len", "collect"))
def subseq_scan(units, dec_sym, dec_len, start_bits, end_bits, total_bits,
                max_len: int, collect: bool = False, lut_base=None):
    """Decode each subsequence window [start_bits[i], end_bits[i]).

    All arrays are vectorized over subsequences.  Returns
    ``(landing_pos, counts[, symbols])`` where ``landing_pos`` is the absolute
    bit position of the first codeword at-or-after ``end_bits`` (the sync
    point handed to the next subsequence) and ``counts`` is the number of
    codewords whose start lies inside the window (clipped at ``total_bits``).

    ``lut_base`` (optional int32[n]) is a per-subsequence offset added to the
    peeked LUT index -- the batched multi-tensor decoder concatenates the
    decode tables of several codebooks and selects per lane.

    With ``collect=True`` also returns uint16[n, MAX_SYMS_PER_SUBSEQ] padded
    symbols.  The loop is a masked fixed-shape ``while_loop`` -- the TPU
    analogue of the paper's per-warp decode with early exit: iteration stops
    as soon as *every* lane has crossed its window end (`__all_sync`), rather
    than after the worst-case 128 iterations.
    """
    start = start_bits.astype(jnp.int32)
    end = jnp.minimum(end_bits.astype(jnp.int32), total_bits)
    n = start.shape[0]

    syms0 = jnp.zeros((n, MAX_SYMS_PER_SUBSEQ), jnp.uint16) if collect else None

    def cond(state):
        pos, count, syms = state
        return jnp.any(pos < end)

    def body(state):
        pos, count, syms = state
        active = pos < end
        win = peek(units, pos, max_len)
        if lut_base is not None:
            win = win + lut_base
        # Guard: keep the LUT gather in bounds even if a malformed stream
        # or merged-LUT offset produced an out-of-range window index.
        win = jnp.clip(win, 0, dec_sym.shape[0] - 1)
        sym = dec_sym[win]
        length = dec_len[win].astype(jnp.int32)
        if collect:
            # Column write: every active lane stores its count-th symbol.
            idx = jnp.clip(count, 0, MAX_SYMS_PER_SUBSEQ - 1)
            upd = jnp.where(active, sym, syms[jnp.arange(n), idx])
            syms = syms.at[jnp.arange(n), idx].set(upd)
        count = jnp.where(active, count + 1, count)
        # A zero-length LUT entry (unused symbol pattern in zero padding)
        # must still advance to guarantee termination.
        pos = jnp.where(active, pos + jnp.maximum(length, 1), pos)
        return pos, count, syms

    pos0 = jnp.minimum(start, end)
    state = (pos0, jnp.zeros(n, jnp.int32), syms0)
    pos, count, syms = jax.lax.while_loop(cond, body, state)
    if collect:
        return pos, count, syms
    return pos, count


# ---------------------------------------------------------------------------
# Self-synchronization phases
# ---------------------------------------------------------------------------


@partial(jax.jit,
         static_argnames=("n_subseq", "max_len", "subseqs_per_seq", "early_exit"))
def selfsync_intra(units, dec_sym, dec_len, total_bits, n_subseq: int,
                   max_len: int, subseqs_per_seq: int, early_exit: bool = True):
    """Phase 1: per-sequence sync-point discovery.

    Every subsequence starts with a candidate offset 0 at its boundary; each
    round decodes all windows and hands the landing position to the next
    subsequence *within the same sequence*.  ``early_exit=True`` terminates
    when the offsets reach a fixed point (the paper's `__all_sync`
    optimization); ``early_exit=False`` always runs the worst-case
    ``subseqs_per_seq`` rounds (the original W&S behaviour the paper
    improves upon).  Returns (start_bits, rounds_executed).
    """
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    ends = boundaries + SUBSEQ_BITS
    start = boundaries  # offset 0 everywhere

    def round_body(state):
        start, _changed, rounds = state
        landing, _ = subseq_scan(units, dec_sym, dec_len, start, ends,
                                 total_bits, max_len)
        # landing[i] becomes the start of subsequence i+1, except across
        # sequence boundaries (handled by selfsync_inter).
        prop = jnp.roll(landing, 1).at[0].set(start[0])
        is_seq_head = (jnp.arange(n_subseq) % subseqs_per_seq) == 0
        new_start = jnp.where(is_seq_head, start, prop)
        changed = jnp.any(new_start != start)
        return new_start, changed, rounds + 1

    if early_exit:
        def cond(state):
            _start, changed, rounds = state
            return jnp.logical_and(changed, rounds < subseqs_per_seq)
        start, _, rounds = jax.lax.while_loop(
            cond, round_body, (start, jnp.bool_(True), jnp.int32(0)))
    else:
        state = (start, jnp.bool_(True), jnp.int32(0))
        for _ in range(subseqs_per_seq):
            state = round_body(state)
        start, _, rounds = state
    return start, rounds


@partial(jax.jit, static_argnames=("max_len", "subseqs_per_seq", "max_rounds"))
def selfsync_inter(units, dec_sym, dec_len, start_bits, total_bits,
                   max_len: int, subseqs_per_seq: int, max_rounds: int = 8):
    """Phase 2: propagate sync points across sequence boundaries.

    The landing position of each sequence's last subsequence seeds the next
    sequence's first subsequence; sequences whose seed changed re-run their
    intra-sequence propagation.  Thanks to self-synchronization the fixed
    point is reached in one or two rounds on real data; ``max_rounds`` bounds
    the adversarial case (correctness does not depend on it because
    propagation from a *true* start is exact, so round k fixes sequence k at
    the latest -- we chain whole-stream propagation inside each round).
    """
    n_subseq = start_bits.shape[0]
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    ends = boundaries + SUBSEQ_BITS

    def round_body(state):
        start, _changed = state
        landing, _ = subseq_scan(units, dec_sym, dec_len, start, ends,
                                 total_bits, max_len)
        prop = jnp.roll(landing, 1).at[0].set(jnp.int32(0))
        new_start = prop  # every subsequence, including sequence heads
        changed = jnp.any(new_start != start)
        return new_start, changed

    def cond(state):
        _start, changed = state
        return changed

    # Bound total rounds: each round is a full window-parallel propagation;
    # composing `max_rounds * subseqs_per_seq` of them covers the stream.
    def bounded_cond(state_rounds):
        state, rounds = state_rounds
        return jnp.logical_and(cond(state), rounds < max_rounds * subseqs_per_seq)

    def bounded_body(state_rounds):
        state, rounds = state_rounds
        return round_body(state), rounds + 1

    (start, _), rounds = jax.lax.while_loop(
        bounded_cond, bounded_body, ((start_bits, jnp.bool_(True)), jnp.int32(0)))
    return start, rounds


def output_offsets(counts):
    """Phase 3: exclusive prefix sum of per-subsequence symbol counts."""
    c = counts.astype(jnp.int32)
    return jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(c)])


# ---------------------------------------------------------------------------
# Decode + write
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("max_len", "n_out"))
def decode_write(units, dec_sym, dec_len, start_bits, total_bits,
                 max_len: int, n_out: int):
    """Phase 4 (baseline layout): padded per-subsequence decode + compaction.

    This reproduces the *original* decoders' write behaviour: each lane
    produces its symbols at strided, data-dependent offsets.  On TPU the
    stride shows up as a full padded (n_subseq, 128) intermediate that is
    then gather-compacted -- ~2x HBM traffic, the structural analogue of the
    uncoalesced global writes the paper fixes.  Kept as the A/B baseline.
    """
    n_subseq = start_bits.shape[0]
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    ends = boundaries + SUBSEQ_BITS
    _, counts, padded = subseq_scan(units, dec_sym, dec_len, start_bits, ends,
                                    total_bits, max_len, collect=True)
    offsets = output_offsets(counts)
    out_pos = jnp.arange(n_out, dtype=jnp.int32)
    owner = jnp.clip(
        jnp.searchsorted(offsets, out_pos, side="right") - 1, 0, n_subseq - 1)
    within = out_pos - offsets[owner]
    return padded[owner, jnp.clip(within, 0, MAX_SYMS_PER_SUBSEQ - 1)], counts


@partial(jax.jit, static_argnames=("max_len", "n_out", "tile_syms", "ss_max"))
def decode_write_tiles(units, dec_sym, dec_len, start_bits, end_bits, offsets,
                       total_bits, max_len: int, n_out: int, tile_syms: int,
                       ss_max: int, lut_base=None):
    """Phase 4 (optimized, paper Alg. 1 analogue): output-tile-centric decode.

    The output is cut into fixed tiles of ``tile_syms`` symbols (the "shared
    memory buffer" -- here a VMEM staging tile).  For each tile we decode the
    (statically bounded) range of subsequences overlapping it and scatter
    *locally* before emitting one dense aligned tile.  ``ss_max`` must be
    >= ``pipeline.ss_max_for_tile(tile_syms, max_len)``.

    ``start_bits``/``end_bits`` are absolute bit windows per subsequence;
    passing them explicitly lets the tuner run this over *gathered* (sorted
    by compression-ratio class) subsequence sets.  ``lut_base`` (optional
    int32[n_subseq]) selects a per-subsequence decode table inside a merged
    LUT (the batched multi-tensor path).

    This jnp version is the oracle for ``repro.kernels.huffman_decode``.
    """
    n_subseq = start_bits.shape[0]
    n_tiles = (n_out + tile_syms - 1) // tile_syms

    tile_base = jnp.arange(n_tiles, dtype=jnp.int32) * tile_syms
    # First subsequence whose output range intersects each tile.
    s0 = jnp.clip(
        jnp.searchsorted(offsets, tile_base, side="right") - 1, 0, n_subseq - 1)

    def decode_tile(t, s0_t):
        subs = jnp.clip(s0_t + jnp.arange(ss_max, dtype=jnp.int32), 0,
                        n_subseq - 1)
        starts = start_bits[subs]
        ends = end_bits[subs]
        lb = None if lut_base is None else lut_base[subs]
        _, counts, padded = subseq_scan(units, dec_sym, dec_len, starts, ends,
                                        total_bits, max_len, collect=True,
                                        lut_base=lb)
        base = tile_base[t]
        local = offsets[subs][:, None] + jnp.arange(MAX_SYMS_PER_SUBSEQ)[None, :] - base
        valid = (
            (jnp.arange(MAX_SYMS_PER_SUBSEQ)[None, :] < counts[:, None])
            & (local >= 0) & (local < tile_syms)
            # guard duplicated (clipped) subsequence rows
            & (subs[:, None] == s0_t + jnp.arange(ss_max, dtype=jnp.int32)[:, None])
        )
        tile = jnp.zeros((tile_syms,), jnp.uint16)
        tile = tile.at[jnp.where(valid, local, tile_syms)].set(
            jnp.where(valid, padded, 0), mode="drop")
        return tile

    tiles = jax.vmap(decode_tile)(jnp.arange(n_tiles), s0)
    return tiles.reshape(-1)[:n_out]


# ---------------------------------------------------------------------------
# Full-pipeline reference decoders
# ---------------------------------------------------------------------------


def gap_starts(stream: EncodedStream):
    n_subseq = stream.gaps.shape[0]
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    return boundaries + stream.gaps.astype(jnp.int32)


def decode_gap_array(stream: EncodedStream, dec_sym, dec_len, max_len: int,
                     n_out: int, tile_syms: int = 4096, use_tiles: bool = True):
    """Gap-array decoder: counts from gap starts, prefix sum, decode+write."""
    starts = gap_starts(stream)
    n_subseq = starts.shape[0]
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    _, counts = subseq_scan(jnp.asarray(stream.units), jnp.asarray(dec_sym),
                            jnp.asarray(dec_len), starts,
                            boundaries + SUBSEQ_BITS, stream.total_bits,
                            max_len)
    offsets = output_offsets(counts)
    if use_tiles:
        from repro.core.huffman.pipeline import ss_max_for_tile

        return decode_write_tiles(stream.units, dec_sym, dec_len, starts,
                                  boundaries + SUBSEQ_BITS, offsets,
                                  stream.total_bits, max_len, n_out,
                                  tile_syms, ss_max_for_tile(tile_syms,
                                                             max_len))
    out, _ = decode_write(stream.units, dec_sym, dec_len, starts,
                          stream.total_bits, max_len, n_out)
    return out


def decode_selfsync(stream: EncodedStream, dec_sym, dec_len, max_len: int,
                    n_out: int, tile_syms: int = 4096, use_tiles: bool = True,
                    early_exit: bool = True):
    """Self-synchronization decoder (no gap array consumed)."""
    units = jnp.asarray(stream.units)
    n_subseq = stream.gaps.shape[0]
    start, _ = selfsync_intra(units, dec_sym, dec_len, stream.total_bits,
                              n_subseq, max_len, stream.subseqs_per_seq,
                              early_exit=early_exit)
    start, _ = selfsync_inter(units, dec_sym, dec_len, start,
                              stream.total_bits, max_len,
                              stream.subseqs_per_seq)
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    _, counts = subseq_scan(units, dec_sym, dec_len, start,
                            boundaries + SUBSEQ_BITS, stream.total_bits,
                            max_len)
    offsets = output_offsets(counts)
    if use_tiles:
        from repro.core.huffman.pipeline import ss_max_for_tile

        return decode_write_tiles(units, dec_sym, dec_len, start,
                                  boundaries + SUBSEQ_BITS, offsets,
                                  stream.total_bits, max_len, n_out,
                                  tile_syms, ss_max_for_tile(tile_syms,
                                                             max_len))
    out, _ = decode_write(units, dec_sym, dec_len, start, stream.total_bits,
                          max_len, n_out)
    return out


@partial(jax.jit, static_argnames=("max_len", "chunk_symbols"))
def decode_chunked(units_rows, chunk_bits, chunk_syms, dec_sym, dec_len,
                   max_len: int, chunk_symbols: int):
    """cuSZ's naive coarse-grained decoder: one sequential scan per chunk."""

    def decode_chunk(units, n_bits):
        def step(pos, _):
            win = peek(units, pos, max_len)
            sym = dec_sym[win]
            length = dec_len[win].astype(jnp.int32)
            valid = pos < n_bits
            return pos + jnp.maximum(length, 1), jnp.where(valid, sym, 0)

        _, syms = jax.lax.scan(step, jnp.int32(0), None, length=chunk_symbols)
        return syms

    return jax.vmap(decode_chunk)(units_rows, chunk_bits.astype(jnp.int32))
