"""Bit-level primitives shared by the jnp reference decoders and the Pallas
kernel bodies (the kernel bodies call these on *values*, never on refs)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.huffman.encode import SUBSEQ_BITS, UNIT_BITS  # re-export

__all__ = ["peek", "SUBSEQ_BITS", "UNIT_BITS"]


def peek(units: jnp.ndarray, pos: jnp.ndarray, max_len: int) -> jnp.ndarray:
    """Read ``max_len`` bits at absolute bit position(s) ``pos``.

    ``units`` is uint32[U] (MSB-first packing); ``pos`` is int32[...].
    Returns int32[...] in [0, 2**max_len) -- an index into the decode LUT.

    Positions may point up to the final bit; we clip unit gathers so a peek
    whose *window* overruns the stream reads zero-padding (the encoder always
    pads the tail with zero bits, and decode loops mask on ``total_bits``).
    """
    pos = pos.astype(jnp.int32)
    u = pos >> 5
    sh = (pos & 31).astype(jnp.uint32)
    n = units.shape[0]
    w0 = units[jnp.clip(u, 0, n - 1)]
    w1 = jnp.where(u + 1 < n, units[jnp.clip(u + 1, 0, n - 1)], jnp.uint32(0))
    hi = w0 << sh
    lo = jnp.where(sh == 0, jnp.uint32(0), w1 >> (jnp.uint32(32) - sh))
    window = hi | lo
    return (window >> jnp.uint32(32 - max_len)).astype(jnp.int32)
