"""Unified plan/execute decoder pipeline (single entry point for decoding).

The paper's decode stack is a fixed phase sequence -- sync-point discovery
(gap array or self-synchronization), per-subsequence count, output-offset
prefix sum, then the tuned tile-staged decode-write.  This module factors
that sequence into two layers so every consumer (``core/sz/compressor``,
``checkpoint/manager``, ``models/kvcache``, the benchmarks) calls one API:

    build_plan()    phases 1-3 + the online tuner's per-CR-class dispatch
                    plan (paper Alg. 2): sync starts, counts, output
                    offsets, CR classes, per-class tile sizes.
    decode()        phase 4 through a named *backend*; strategies:
                    "tuned"  per-CR-class tile decode (paper Alg. 1 + 2),
                    "tile"   fixed-tile staged decode (paper Alg. 1),
                    "padded" padded-layout baseline (the original decoders'
                             uncoalesced-write cost structure).
    decode_batch()  class-merged decode of MANY tensors: sequences of equal
                    CR class from all tensors are gathered into one
                    decode-write dispatch, so N checkpoint shards or
                    KV-cache blocks cost one dispatch per class instead of
                    N x classes (the cuSZ+-style batched dispatch).

Backends live in a small registry: "ref" is the pure-jnp reference
(``core.huffman.decode``), "pallas" the kernel path (``repro.kernels.ops``,
imported lazily so core stays jnp-only until kernels are requested).  Every
backend counts its decode-write dispatches in ``backend.stats`` -- tests
assert the batched path issues at most one dispatch per CR class.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import codebook as _cb
from repro.core.huffman import decode as hd
from repro.core.huffman.bits import SUBSEQ_BITS, UNIT_BITS
from repro.core.huffman.encode import EncodedStream


class DecodeGuardError(RuntimeError):
    """A decoder-level integrity guard tripped on malformed input.

    Raised by ``build_plan`` (corrupt codebook: Kraft violation, lengths
    over ``max_len``, bad LUT shapes) and by the symbol-count guard in
    ``sz.compressor.decompress`` when a CRC-valid-but-malformed stream
    would decode the wrong number of symbols.  Every trip -- including
    non-raising containment such as gap clamping -- is counted in
    ``backend.stats["decode_guard_trips"]``.
    """

# Paper Alg. 2 constants: class c in {1..T_high} covers CR in (c-1, c];
# class T_high+1 covers (T_high, 16].
T_HIGH_DEFAULT = 8          # paper's V100 value; VMEM budget gives the same
OVERFLOW_TILE = 3584        # paper: optimal buffer for CR > T_high on V100
SYMBOL_BYTES = 2
DEFAULT_TILE_SYMS = 4096

#: Decode-write strategies accepted by ``decode`` (and ``CodecConfig``).
VALID_STRATEGIES = ("tuned", "tile", "padded")
#: Sync-discovery methods accepted by ``build_plan`` / ``decode_batch``.
VALID_PLAN_METHODS = ("gap", "selfsync")


def ss_max_for_tile(tile_syms: int, max_len: int) -> int:
    """Static bound on subsequences overlapping one ``tile_syms`` output tile.

    Every codeword is at most ``max_len`` bits, so a 128-bit subsequence
    contains at least ``(SUBSEQ_BITS - max_len) // max_len + 1`` codeword
    starts (``Codebook.min_starts_per_subseq``).  A tile therefore overlaps
    at most ``tile_syms / min_starts`` whole subsequences, plus one partial
    subsequence at each edge.  This is the single audited home of the
    formula -- the decode-write kernels' lane provisioning and the VMEM
    scratch sizing both key off it.
    """
    min_starts = (SUBSEQ_BITS - max_len) // max_len + 1
    return tile_syms // min_starts + 2


# ---------------------------------------------------------------------------
# CR classification (paper Alg. 2: CLASSIFY / HISTOGRAM / SORT / plan)
# ---------------------------------------------------------------------------


def sequence_ratios(seq_counts: jnp.ndarray, subseqs_per_seq: int):
    """Per-sequence compression ratio: decoded bytes / encoded bytes."""
    enc_bytes = subseqs_per_seq * SUBSEQ_BITS // 8
    return seq_counts.astype(jnp.float32) * SYMBOL_BYTES / enc_bytes


def classify(ratios: jnp.ndarray, t_high: int = T_HIGH_DEFAULT):
    """CLASSIFYCR: CR in (c-1, c] -> class c; CR > t_high -> t_high + 1."""
    cls = jnp.ceil(ratios).astype(jnp.int32)
    return jnp.clip(cls, 1, t_high + 1)


def class_histogram(classes: jnp.ndarray, t_high: int = T_HIGH_DEFAULT):
    """ParHISTOGRAM (jnp fallback; the Pallas kernel lives in repro.kernels)."""
    return jnp.bincount(classes, length=t_high + 2)


def sort_by_class(classes: jnp.ndarray):
    """ParKeyValueSort: stable key-value sort of sequence ids by class."""
    idx = jnp.arange(classes.shape[0], dtype=jnp.int32)
    keys, vals = jax.lax.sort_key_val(classes, idx, is_stable=True)
    return keys, vals


def tile_for_class(c: int, t_high: int = T_HIGH_DEFAULT) -> int:
    """Buffer (tile) size for a class: 1024 symbols per CR unit, as in the
    paper ("sequences in the (3,4] group ... buffer of length 4096"), with
    the overflow class pinned at OVERFLOW_TILE."""
    if c > t_high:
        return OVERFLOW_TILE
    return 1024 * max(c, 1)


@dataclasses.dataclass
class ClassPlan:
    """Host-side per-CR-class dispatch plan (per-class sequence id lists)."""

    t_high: int
    classes: np.ndarray          # int32[n_seq]
    seq_order: np.ndarray        # int32[n_seq] sequence ids sorted by class
    class_start: np.ndarray      # int32[t_high+3] prefix offsets into seq_order
    tile_syms: dict              # class -> tile size

    def class_seq_ids(self, c: int) -> np.ndarray:
        lo, hi = int(self.class_start[c]), int(self.class_start[c + 1])
        return self.seq_order[lo:hi]


def make_plan(stream, seq_counts, subseqs_per_seq: int,
              t_high: int = T_HIGH_DEFAULT) -> ClassPlan:
    """Build the per-CR-class dispatch plan from per-sequence symbol counts.

    ``stream`` is accepted (and ignored) so callers that already hold the
    encoded stream can pass it alongside its metadata unchanged.
    """
    del stream
    ratios = sequence_ratios(jnp.asarray(seq_counts), subseqs_per_seq)
    classes = classify(ratios, t_high)
    hist = class_histogram(classes, t_high)
    keys, order = sort_by_class(classes)
    class_start = np.zeros(t_high + 3, np.int32)
    class_start[1:] = np.cumsum(np.asarray(hist))
    return ClassPlan(
        t_high=t_high,
        classes=np.asarray(classes),
        seq_order=np.asarray(order),
        class_start=class_start,
        tile_syms={c: tile_for_class(c, t_high) for c in range(1, t_high + 2)},
    )


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OutputTransform:
    """Fused decode epilogue: dequantization + inverse Lorenzo, attached to
    a decode call so phase 4 emits reconstructed floats directly.

    The transform is ``x = 2*eb * cumsum(code - radius)`` with the outlier
    side list (``outlier_pos`` int32[m_pad] flat positions, -1 padded;
    ``outlier_val`` the exact residuals) scattered in before the prefix sum
    -- exactly ``core.sz.lorenzo.dequantize``.  Backends that register
    fused phase-4 ops apply it inside the decode-write dispatch, so the
    uint16 quant-code array is never materialized in HBM between decode and
    reconstruction.

    ``shape`` selects the reconstruction geometry: ``None`` (or any shape
    with at most one non-unit axis) runs the 1-D chained-carry epilogue,
    2-D/3-D shapes run the row/plane-carry epilogue (cumsum along every
    axis).  ``out_dtype`` is the reconstruction output dtype; the epilogue
    computes in f32 and casts once at the end, matching
    ``lorenzo.dequantize`` bit-for-bit for bf16/f16.  Both default to the
    historical 1-D float32 behavior.
    """

    eb: float
    radius: int
    outlier_pos: Any
    outlier_val: Any
    shape: Any = None
    out_dtype: Any = None


@dataclasses.dataclass
class DecodeBackend:
    """One implementation of the decode phases.

    ``count_fn``  (units, ds, dl, start_abs, end_abs, total_bits, max_len)
                  -> counts
    ``sync_fn``   (units, ds, dl, total_bits, n_subseq, sps, max_len,
                  early_exit) -> (start_abs, counts)
    ``tiles_fn``  phase-4 tile decode; signature of
                  ``decode.decode_write_tiles`` (+ optional ``lut_base``)
    ``padded_fn`` phase-4 padded baseline: (units, ds, dl, start_abs,
                  end_abs, total_bits, max_len, n_out) -> out

    Optional fused phase-4 ops (decode + dequantize + reconstruct in one
    dispatch; see :class:`OutputTransform`):

    ``fused_tiles_fn``   tiles_fn signature + (opos, oval, eb, radius,
                         shape=, out_dtype=) -> reconstructed
                         ``out_dtype[n_out]`` (flat, C-order)
    ``fused_padded_fn``  padded_fn signature + (opos, oval, eb, radius,
                         shape=, out_dtype=) -> reconstructed
                         ``out_dtype[n_out]`` (flat, C-order)

    A backend registered without them still works everywhere; fused
    requests fall back to the two-pass path and the fallback is recorded
    in ``stats["fused_fallbacks"]``.
    """

    name: str
    count_fn: Callable
    sync_fn: Callable
    tiles_fn: Callable
    padded_fn: Callable
    fused_tiles_fn: "Callable | None" = None
    fused_padded_fn: "Callable | None" = None
    stats: dict = dataclasses.field(
        default_factory=lambda: {"decode_write_dispatches": 0,
                                 "plan_builds": 0,
                                 "fused_dispatches": 0,
                                 "fused_fallbacks": 0,
                                 "decode_guard_trips": 0})
    _stats_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @property
    def supports_fused(self) -> bool:
        return (self.fused_tiles_fn is not None
                and self.fused_padded_fn is not None)

    def bump(self, key: str, n: int = 1):
        """Atomic counter increment: one backend handle is shared by every
        codec on that backend, including N serving threads decoding through
        one scheduler, so a bare ``+=`` would drop counts."""
        with self._stats_lock:
            self.stats[key] += n

    def reset_stats(self):
        with self._stats_lock:
            for k in self.stats:
                self.stats[k] = 0

    # Counted dispatch wrappers: every phase-4 launch goes through these.
    def decode_tiles(self, *args, **kwargs):
        self.bump("decode_write_dispatches")
        return self.tiles_fn(*args, **kwargs)

    def decode_padded(self, *args, **kwargs):
        self.bump("decode_write_dispatches")
        return self.padded_fn(*args, **kwargs)

    def decode_tiles_fused(self, *args, **kwargs):
        self.bump("decode_write_dispatches")
        self.bump("fused_dispatches")
        return self.fused_tiles_fn(*args, **kwargs)

    def decode_padded_fused(self, *args, **kwargs):
        self.bump("decode_write_dispatches")
        self.bump("fused_dispatches")
        return self.fused_padded_fn(*args, **kwargs)


_BACKEND_FACTORIES: dict[str, Callable[[], DecodeBackend]] = {}
_BACKENDS: dict[str, DecodeBackend] = {}


def register_backend(name: str, factory: Callable[[], DecodeBackend]):
    """Register (or replace) a decode backend under ``name``.

    ``factory`` is a zero-argument callable returning a ``DecodeBackend``;
    it runs lazily on the first ``get_backend(name)`` so expensive imports
    (e.g. the Pallas kernels) are deferred until the backend is requested.
    Re-registering a name drops the previously constructed handle, so the
    next ``get_backend`` call sees the new factory.  Backends may omit the
    fused phase-4 ops (``fused_tiles_fn`` / ``fused_padded_fn``); fused
    requests then fall back to two-pass decoding, counted in
    ``stats["fused_fallbacks"]``.
    """
    _BACKEND_FACTORIES[name] = factory
    _BACKENDS.pop(name, None)


def available_backends() -> list[str]:
    return sorted(_BACKEND_FACTORIES)


def get_backend(backend: "str | DecodeBackend") -> DecodeBackend:
    if isinstance(backend, DecodeBackend):
        return backend
    if backend not in _BACKEND_FACTORIES:
        raise ValueError(
            f"unknown backend {backend!r}; available: {available_backends()}")
    if backend not in _BACKENDS:
        _BACKENDS[backend] = _BACKEND_FACTORIES[backend]()
    return _BACKENDS[backend]


def _make_ref_backend() -> DecodeBackend:
    def count(units, ds, dl, start_abs, end_abs, total_bits, max_len):
        _, counts = hd.subseq_scan(jnp.asarray(units), ds, dl, start_abs,
                                   end_abs, total_bits, max_len)
        return counts

    def sync(units, ds, dl, total_bits, n_subseq, sps, max_len,
             early_exit=True):
        units = jnp.asarray(units)
        start, _ = hd.selfsync_intra(units, ds, dl, total_bits, n_subseq,
                                     max_len, sps, early_exit=early_exit)
        start, _ = hd.selfsync_inter(units, ds, dl, start, total_bits,
                                     max_len, sps)
        ends = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS + SUBSEQ_BITS
        _, counts = hd.subseq_scan(units, ds, dl, start, ends, total_bits,
                                   max_len)
        return start, counts

    def padded(units, ds, dl, start_abs, end_abs, total_bits, max_len, n_out):
        del end_abs  # the padded reference derives windows from boundaries
        out, _ = hd.decode_write(jnp.asarray(units), ds, dl, start_abs,
                                 total_bits, max_len, n_out)
        return out

    def _epilogue(codes, n_out, opos, oval, eb, radius, shape, out_dtype):
        # Lazy import: core.sz -> compressor -> pipeline at package import
        # time, so pipeline cannot import core.sz at its own top level.
        from repro.core.sz import lorenzo

        shape = tuple(shape) if shape is not None else (n_out,)
        dtype = jnp.dtype(out_dtype) if out_dtype is not None else jnp.float32
        out = lorenzo.dequantize(codes.reshape(shape),
                                 jnp.asarray(opos, jnp.int32),
                                 jnp.asarray(oval, jnp.int32), eb, shape,
                                 radius=radius, dtype=dtype)
        return out.reshape(-1)

    # The ref backend composes the existing jnp paths (decode, then the
    # exact N-D dequantize/reconstruct the two-pass path uses), so fused-
    # vs-two-pass parity is testable on every platform by construction:
    # these are the jnp mirrors of ``kernels/fused_decode.py`` for every
    # supported ndim/dtype.
    def fused_tiles(units, ds, dl, starts, ends, offsets, total_bits,
                    max_len, n_out, tile_syms, ss_max, opos, oval, eb,
                    radius, shape=None, out_dtype=None, **kwargs):
        codes = hd.decode_write_tiles(jnp.asarray(units), ds, dl, starts,
                                      ends, offsets, total_bits, max_len,
                                      n_out, tile_syms, ss_max, **kwargs)
        return _epilogue(codes, n_out, opos, oval, eb, radius, shape,
                         out_dtype)

    def fused_padded(units, ds, dl, start_abs, end_abs, total_bits, max_len,
                     n_out, opos, oval, eb, radius, shape=None,
                     out_dtype=None):
        codes = padded(units, ds, dl, start_abs, end_abs, total_bits,
                       max_len, n_out)
        return _epilogue(codes, n_out, opos, oval, eb, radius, shape,
                         out_dtype)

    return DecodeBackend(name="ref", count_fn=count, sync_fn=sync,
                         tiles_fn=hd.decode_write_tiles, padded_fn=padded,
                         fused_tiles_fn=fused_tiles,
                         fused_padded_fn=fused_padded)


def _make_pallas_backend(interpret: bool = True) -> DecodeBackend:
    """Kernel backend.  ``interpret=True`` runs the Pallas interpreter (the
    CPU-safe default of this container); ``interpret=False`` compiles the
    kernels for the accelerator (registered as "pallas-compiled")."""
    import functools

    from repro.kernels import ops  # lazy: keeps core jnp-only by default

    def count(units, ds, dl, start_abs, end_abs, total_bits, max_len):
        counts, _ = ops.subseq_counts(units, ds, dl, start_abs, end_abs,
                                      total_bits, max_len,
                                      interpret=interpret)
        return counts

    def sync(units, ds, dl, total_bits, n_subseq, sps, max_len,
             early_exit=True):
        start, counts, _ = ops.selfsync_sync(units, ds, dl, total_bits,
                                             n_subseq, sps, max_len,
                                             early_exit=early_exit,
                                             interpret=interpret)
        return start, counts

    def padded(units, ds, dl, start_abs, end_abs, total_bits, max_len, n_out):
        out, _ = ops.decode_padded_compact(units, ds, dl, start_abs, end_abs,
                                           total_bits, max_len, n_out,
                                           interpret=interpret)
        return out

    name = "pallas" if interpret else "pallas-compiled"
    return DecodeBackend(
        name=name, count_fn=count, sync_fn=sync,
        tiles_fn=functools.partial(ops.decode_write_tiles,
                                   interpret=interpret),
        padded_fn=padded,
        fused_tiles_fn=functools.partial(ops.decode_write_tiles_fused,
                                         interpret=interpret),
        fused_padded_fn=functools.partial(ops.decode_padded_fused,
                                          interpret=interpret))


register_backend("ref", _make_ref_backend)
register_backend("pallas", _make_pallas_backend)
register_backend("pallas-compiled",
                 lambda: _make_pallas_backend(interpret=False))


# ---------------------------------------------------------------------------
# Encode-side backend registry (the write-path twin of the decode registry)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EncodeBackend:
    """One implementation of the encode phases (quantize/histogram/bit-pack).

    ``device=True`` backends keep the full-size arrays resident: quantize
    runs in-graph (f32), the histogram kernel reduces the codes on device,
    and the only host transfer before the bit-pack dispatch is the
    ``2*radius``-entry histogram (codebook construction is host numpy --
    the ISSUE-sanctioned small transfer).  The "ref" backend is the host
    path (f64 prequantization + numpy histogram), kept as the storage-grade
    oracle.

    ``quantize_fn``  (x, abs_eb, radius) -> (codes u16, outlier bool,
                     residual i32), shapes matching ``x``
    ``hist_fn``      (codes, nbins) -> int32[nbins]
    ``pack_fn``      (symbols, enc_code, enc_len, total_bits, sps, min_len)
                     -> ``EncodedStream``

    Every bit-pack launch is counted in ``stats["encode_dispatches"]``;
    compress requests a device backend cannot serve (non-float32 inputs)
    fall back to the host path, counted in ``stats["encode_fallbacks"]``,
    never wrong.
    """

    name: str
    device: bool
    quantize_fn: Callable
    hist_fn: Callable
    pack_fn: Callable
    stats: dict = dataclasses.field(
        default_factory=lambda: {"encode_dispatches": 0,
                                 "encode_fallbacks": 0,
                                 "encoder_plan_builds": 0})
    _stats_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def bump(self, key: str, n: int = 1):
        """Atomic counter increment (see ``DecodeBackend.bump``)."""
        with self._stats_lock:
            self.stats[key] += n

    def reset_stats(self):
        with self._stats_lock:
            for k in self.stats:
                self.stats[k] = 0

    def pack(self, symbols, enc_code, enc_len, total_bits, sps, min_len):
        self.bump("encode_dispatches")
        return self.pack_fn(symbols, enc_code, enc_len, total_bits, sps,
                            min_len)


_ENCODE_FACTORIES: dict[str, Callable[[], EncodeBackend]] = {}
_ENCODE_BACKENDS: dict[str, EncodeBackend] = {}


def register_encode_backend(name: str, factory: Callable[[], EncodeBackend]):
    """Register (or replace) an encode backend under ``name`` (lazy factory,
    same contract as :func:`register_backend`)."""
    _ENCODE_FACTORIES[name] = factory
    _ENCODE_BACKENDS.pop(name, None)


def available_encode_backends() -> list[str]:
    return sorted(_ENCODE_FACTORIES)


def get_encode_backend(backend: "str | EncodeBackend") -> EncodeBackend:
    if isinstance(backend, EncodeBackend):
        return backend
    if backend not in _ENCODE_FACTORIES:
        raise ValueError(f"unknown encode backend {backend!r}; available: "
                         f"{available_encode_backends()}")
    if backend not in _ENCODE_BACKENDS:
        _ENCODE_BACKENDS[backend] = _ENCODE_FACTORIES[backend]()
    return _ENCODE_BACKENDS[backend]


def _host_quantize(x, abs_eb, radius):
    from repro.core.sz import lorenzo  # lazy: core.sz imports this module

    return lorenzo.quantize_host(np.asarray(x), abs_eb, radius=radius)


def _jnp_quantize(x, abs_eb, radius):
    from repro.core.sz import lorenzo

    return lorenzo.quantize(jnp.asarray(x), abs_eb, radius=radius)


def _ref_pack(symbols, enc_code, enc_len, total_bits, sps, min_len):
    del min_len  # only sizes the gather/kernel lane budgets
    from repro.core.huffman import encode as he

    symbols = jnp.asarray(symbols)
    if symbols.shape[0] == 0:
        return he.empty_stream(sps)
    return he._encode_padded(symbols, jnp.asarray(enc_code),
                             jnp.asarray(enc_len),
                             n_units_padded=he.units_for_bits(total_bits, sps),
                             subseqs_per_seq=sps)


def _gather_pack(symbols, enc_code, enc_len, total_bits, sps, min_len):
    from repro.core.huffman import encode as he

    return he.encode_gather(jnp.asarray(symbols), enc_code, enc_len,
                            total_bits, subseqs_per_seq=sps, min_len=min_len)


@functools.partial(jax.jit, static_argnames=("nbins", "chunk"))
def _sorted_histogram(codes, nbins: int, chunk: int = 4096):
    """Exact histogram via chunked sort + per-row edge searchsorted.

    XLA lowers a scatter-add histogram (``jnp.bincount``) to a serial
    scatter; sorting fixed-size rows and differencing the edge positions is
    the same O(n) answer built from primitives that vectorize.  Rows are
    padded with ``nbins`` (an out-of-range key) so the tail never perturbs
    a real bin.
    """
    flat = codes.reshape(-1).astype(jnp.int32)
    pad = (-flat.shape[0]) % chunk
    rows = jnp.pad(flat, (0, pad), constant_values=nbins).reshape(-1, chunk)
    rows = jnp.sort(rows, axis=1)
    edges = jnp.arange(nbins + 1, dtype=jnp.int32)
    cuts = jax.vmap(lambda r: jnp.searchsorted(r, edges, side="left"))(rows)
    return (cuts[:, 1:] - cuts[:, :-1]).sum(axis=0).astype(jnp.int32)


def _make_ref_encode_backend() -> EncodeBackend:
    """The current host path: f64 prequantization, numpy histogram, and the
    jit bit materialization sized from a host pass over the symbols."""
    def hist(codes, nbins):
        return np.bincount(np.asarray(codes).reshape(-1), minlength=nbins)

    return EncodeBackend(name="ref", device=False,
                         quantize_fn=_host_quantize, hist_fn=hist,
                         pack_fn=_ref_pack)


def _make_jnp_encode_backend() -> EncodeBackend:
    """Device-resident pure-jnp pipeline: in-graph f32 quantize, sorted
    device histogram, and the per-unit gather bit-pack -- sized from the
    histogram, so no full-size array crosses to host (the timeable device
    proxy of the kernel backends, exactly like "ref" on the decode side)."""
    return EncodeBackend(name="jnp", device=True, quantize_fn=_jnp_quantize,
                         hist_fn=_sorted_histogram, pack_fn=_gather_pack)


def _make_pallas_encode_backend(interpret: bool = True) -> EncodeBackend:
    """Kernel backend: Lorenzo quantize + histogram + bit-pack kernels
    (``interpret=True`` is the CPU-safe default of this container)."""
    from repro.kernels import ops  # lazy: keeps core jnp-only by default

    def quantize(x, abs_eb, radius):
        x = jnp.asarray(x)
        if x.ndim == 1:
            return ops.lorenzo_quantize(x, abs_eb, radius=radius,
                                        interpret=interpret)
        return _jnp_quantize(x, abs_eb, radius)

    def pack(symbols, enc_code, enc_len, total_bits, sps, min_len):
        return ops.encode_bitpack(symbols, enc_code, enc_len, total_bits,
                                  sps, min_len=min_len, interpret=interpret)

    name = "pallas" if interpret else "pallas-compiled"
    return EncodeBackend(
        name=name, device=True, quantize_fn=quantize,
        hist_fn=functools.partial(ops.histogram, interpret=interpret),
        pack_fn=pack)


register_encode_backend("ref", _make_ref_encode_backend)
register_encode_backend("jnp", _make_jnp_encode_backend)
register_encode_backend("pallas", _make_pallas_encode_backend)
register_encode_backend("pallas-compiled",
                        lambda: _make_pallas_encode_backend(interpret=False))


@dataclasses.dataclass
class EncoderPlan:
    """Everything the bit-pack dispatch needs, sized without touching the
    symbol array: the canonical codebook (host package-merge over the
    histogram), its tables as device arrays, and the exact payload size
    ``total_bits = sum(freq * code_lengths)`` -- so a device backend's only
    pre-pack host transfer is the ``2*radius``-entry histogram."""

    codebook: Any               # core.huffman.codebook.Codebook
    enc_code: jnp.ndarray       # uint32[K] on device
    enc_len: jnp.ndarray        # uint8[K] on device
    total_bits: int
    subseqs_per_seq: int

    @property
    def min_len(self) -> int:
        return self.codebook.min_len


def build_encoder_plan(freq, max_len: int, subseqs_per_seq: int,
                       backend: "str | EncodeBackend" = "ref") -> EncoderPlan:
    """Histogram -> canonical length-limited codebook -> placement sizes.

    ``freq`` may live on device; the host transfer of these ``2*radius``
    counts is the entire host involvement of a device-backend encode (the
    package-merge length limiting stays numpy, as the ISSUE sanctions).
    Counted in ``backend.stats["encoder_plan_builds"]``.
    """
    from repro.core.huffman import codebook as cb

    be = get_encode_backend(backend)
    be.bump("encoder_plan_builds")
    freq_np = np.asarray(freq, dtype=np.int64)
    book = cb.build_codebook(freq_np, max_len=max_len)
    total_bits = int((freq_np * book.enc_len.astype(np.int64)).sum())
    return EncoderPlan(codebook=book,
                       enc_code=jnp.asarray(book.enc_code),
                       enc_len=jnp.asarray(book.enc_len),
                       total_bits=total_bits,
                       subseqs_per_seq=subseqs_per_seq)


def encode_with_plan(symbols, plan: EncoderPlan,
                     backend: "str | EncodeBackend" = "ref") -> EncodedStream:
    """Bit-pack ``symbols`` through ``backend`` under a prebuilt plan.

    The emitted ``EncodedStream`` layout is identical across backends
    (asserted bit-exact by the encode parity matrix in tests), so decode
    never knows which backend wrote the bytes.
    """
    be = get_encode_backend(backend)
    return be.pack(symbols, plan.enc_code, plan.enc_len, plan.total_bits,
                   plan.subseqs_per_seq, plan.min_len)


# ---------------------------------------------------------------------------
# Plan construction (phases 1-3 + classification)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeLuts:
    """Minimal decode-table view: what ``decode()`` needs of a Codebook."""

    dec_sym: Any
    dec_len: Any
    max_len: int


def _as_luts(codebook) -> DecodeLuts:
    return DecodeLuts(dec_sym=jnp.asarray(codebook.dec_sym),
                      dec_len=jnp.asarray(codebook.dec_len),
                      max_len=int(codebook.max_len))


@dataclasses.dataclass
class DecoderPlan:
    """Everything phase 4 needs: sync starts, counts, offsets, CR classes."""

    method: str                 # "gap" | "selfsync"
    start_bits: jnp.ndarray     # int32[n_subseq] absolute sync starts
    end_bits: jnp.ndarray       # int32[n_subseq] absolute window ends
    counts: jnp.ndarray         # int32[n_subseq] codeword starts per window
    offsets: jnp.ndarray        # int32[n_subseq+1] exclusive prefix sum
    seq_counts: np.ndarray      # int64[n_seq] symbols per sequence
    classes: ClassPlan          # per-CR-class dispatch plan
    subseqs_per_seq: int
    t_high: int


def build_plan(stream: EncodedStream, codebook, method: str = "gap",
               backend: "str | DecodeBackend" = "ref",
               t_high: int = T_HIGH_DEFAULT,
               early_exit: bool = True) -> DecoderPlan:
    """Run decode phases 1-3 on ``backend`` and classify sequences by CR.

    Phase 1-2 discovers the per-subsequence sync points -- from the stored
    gap array (``method="gap"``) or by self-synchronization
    (``method="selfsync"``, with ``early_exit`` controlling the paper's
    ``__all_sync`` round termination) -- and counts the codewords per
    128-bit window; phase 3 prefix-sums the counts into output offsets.
    The per-sequence symbol counts then feed the online tuner (paper
    Alg. 2): sequences are classified by compression ratio into classes
    ``1..t_high+1`` and sorted into the per-class dispatch lists of
    ``ClassPlan``.

    The returned ``DecoderPlan`` is backend-portable (device arrays plus
    host metadata, no backend handles) and content-addressable: the
    ``Codec`` / store layers cache plans keyed by payload digest, and
    every build is counted in ``backend.stats["plan_builds"]`` so tests
    and benchmarks can assert cache hits.
    """
    be = get_backend(backend)
    be.bump("plan_builds")
    problems = _cb.validate_codebook(codebook)
    if problems:
        be.bump("decode_guard_trips")
        raise DecodeGuardError("corrupt codebook rejected at build_plan: "
                               + "; ".join(problems))
    luts = _as_luts(codebook)
    units = jnp.asarray(stream.units)
    n_subseq = stream.n_subseq
    sps = stream.subseqs_per_seq
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    ends = boundaries + SUBSEQ_BITS

    if method == "gap":
        # A valid gap never exceeds SUBSEQ_BITS (the encoder stores the
        # offset of the first codeword start inside a 128-bit window, or
        # the in-window distance to end-of-stream).  Clamp anything larger
        # -- a corrupt gap array -- so sync starts stay inside the window
        # their counts were computed for, and count the containment.
        gaps = stream.gaps.astype(jnp.int32)
        if stream.gaps.size and int(np.asarray(stream.gaps).max(
                initial=0)) > SUBSEQ_BITS:
            be.bump("decode_guard_trips")
            gaps = jnp.minimum(gaps, SUBSEQ_BITS)
        starts = boundaries + gaps
        counts = be.count_fn(units, luts.dec_sym, luts.dec_len, starts, ends,
                             stream.total_bits, luts.max_len)
    elif method == "selfsync":
        starts, counts = be.sync_fn(units, luts.dec_sym, luts.dec_len,
                                    stream.total_bits, n_subseq, sps,
                                    luts.max_len, early_exit=early_exit)
    else:
        raise ValueError(f"unknown method {method!r}; valid methods: "
                         f"{list(VALID_PLAN_METHODS)}")

    counts = jnp.asarray(counts)
    offsets = hd.output_offsets(counts)
    seq_counts = np.asarray(counts).reshape(-1, sps).sum(
        axis=1, dtype=np.int64)
    classes = make_plan(None, seq_counts, sps, t_high)
    return DecoderPlan(method=method, start_bits=jnp.asarray(starts),
                       end_bits=ends, counts=counts, offsets=offsets,
                       seq_counts=seq_counts, classes=classes,
                       subseqs_per_seq=sps, t_high=t_high)


# ---------------------------------------------------------------------------
# Execution (phase 4)
# ---------------------------------------------------------------------------


def _pad_pow2(n: int, lo: int = 8) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


def _max_tile_span(offsets: np.ndarray, tile_syms: int, n_sym: int) -> int:
    """Most subsequences any ``tile_syms``-symbol output tile overlaps.

    ``offsets`` is the exclusive prefix sum over the gathered subsequences
    (host int64).  Matches the ``searchsorted`` tile->subsequence mapping of
    the decode-write kernels.
    """
    if n_sym <= 0 or offsets.shape[0] <= 1:
        return 1
    n_tiles = (n_sym + tile_syms - 1) // tile_syms
    base = np.arange(n_tiles, dtype=np.int64) * tile_syms
    s0 = np.searchsorted(offsets, base, side="right") - 1
    last = np.minimum(base + tile_syms, n_sym) - 1
    s1 = np.maximum(np.searchsorted(offsets, last, side="right") - 1, s0)
    return int((s1 - s0 + 1).max())


def _class_dispatch(tiles_fn, units, dec_sym, dec_len, max_len: int,
                    total_bits, tensors: list, t_high: int) -> list:
    """Per-CR-class decode-write over one or many tensors.

    ``tensors`` holds one dict per decoded tensor:
      starts / ends / counts : int32[n_seq * sps] (bit positions already
                               shifted into the merged unit space)
      sps                    : subsequences per sequence
      seq_counts             : int64[n_seq] (host)
      seq_out_start          : int64[n_seq+1] global output offsets (host)
      classes                : ClassPlan
      lut_base               : int or None -- offset into the merged LUT
      n_out                  : output symbol count

    For every class, the matching sequences of ALL tensors are gathered into
    ONE ``tiles_fn`` dispatch (this is the batching the cuSZ+ line of work
    gets from per-class kernel launches); class-local output is then
    scattered back to each tensor's global positions.
    """
    outs = [jnp.zeros((m["n_out"],), jnp.uint16) for m in tensors]
    use_lut_base = any(m["lut_base"] is not None for m in tensors)

    for c in range(1, t_high + 2):
        sel = []                     # (tensor index, seq ids of class c)
        class_n = 0
        for ti, m in enumerate(tensors):
            ids = m["classes"].class_seq_ids(c)
            if ids.size:
                sel.append((ti, ids))
                class_n += int(m["seq_counts"][ids].sum())
        if not sel:
            continue

        tile = tile_for_class(c, t_high)
        class_n_pad = _pad_pow2(max(class_n, 1))

        # Gather the class's subsequences, DROPPING count-0 lanes (the
        # zero-padded tail of each tensor's final sequence).  Dead lanes
        # carry no symbols but would consume tile-decode lanes: a tile's
        # symbol range could then span more subsequences than ``ss_max``
        # provisions, silently dropping the symbols past the lane budget.
        starts_p, ends_p, counts_p, lut_p = [], [], [], []
        for ti, ids in sel:
            m = tensors[ti]
            sps = m["sps"]
            cnt_rows = m["counts_np"].reshape(-1, sps)[ids].reshape(-1)
            keep = jnp.asarray(np.nonzero(cnt_rows > 0)[0].astype(np.int32))
            row = jnp.asarray(ids, jnp.int32)
            starts_p.append(m["starts"].reshape(-1, sps)[row].reshape(-1)[keep])
            ends_p.append(m["ends"].reshape(-1, sps)[row].reshape(-1)[keep])
            counts_p.append(cnt_rows[cnt_rows > 0])
            if use_lut_base:
                lut_p.append(np.full(counts_p[-1].shape[0],
                                     m["lut_base"] or 0, np.int32))
        g_counts_np = np.concatenate(counts_p).astype(np.int64)
        # Pad the gathered subsequence set and the class output to powers of
        # two so the jit cache stays bounded across class populations.
        n_ss = g_counts_np.shape[0]
        n_ss_pad = _pad_pow2(n_ss)
        pad = n_ss_pad - n_ss
        if pad:
            # Inactive pad lanes: start == end == 0 decodes nothing, zero
            # counts keep the offsets flat past the real output.
            z = jnp.zeros((pad,), jnp.int32)
            starts_p.append(z)
            ends_p.append(z)
            if use_lut_base:
                lut_p.append(np.zeros((pad,), np.int32))
        g_starts = jnp.concatenate(starts_p)
        g_ends = jnp.concatenate(ends_p)
        offs_np = np.zeros(n_ss_pad + 1, np.int64)
        offs_np[1:1 + n_ss] = np.cumsum(g_counts_np)
        offs_np[1 + n_ss:] = offs_np[n_ss]
        g_offsets = jnp.asarray(offs_np.astype(np.int32))

        # Lane provisioning: the static bound assumes every subsequence in a
        # tile's span carries >= min_starts codewords; the (at most one per
        # tensor) partial subsequence at a stream tail can carry fewer, so
        # also bound by the worst ACTUAL span any tile needs.
        ss_max = max(ss_max_for_tile(tile, max_len),
                     _max_tile_span(offs_np[:1 + n_ss], tile, class_n) + 2)
        ss_max = -(-ss_max // 8) * 8   # round up: bounds jit-cache variants

        kwargs = {}
        if use_lut_base:
            kwargs["lut_base"] = jnp.asarray(np.concatenate(lut_p))

        class_out = tiles_fn(units, dec_sym, dec_len, g_starts, g_ends,
                             g_offsets, total_bits, max_len, class_n_pad,
                             tile, ss_max, **kwargs)

        # Scatter class-local output back to each tensor's global positions.
        base = 0
        for ti, ids in sel:
            m = tensors[ti]
            cnt, sos = m["seq_counts"], m["seq_out_start"]
            n_t = int(cnt[ids].sum())
            if n_t:
                pos = np.concatenate([
                    np.arange(sos[s], sos[s] + cnt[s], dtype=np.int64)
                    for s in ids])
                outs[ti] = outs[ti].at[jnp.asarray(pos)].set(
                    class_out[base:base + n_t])
            base += n_t
    return outs


def _tensor_meta(plan: DecoderPlan, n_out: int, bit_offset: int = 0,
                 lut_base: "int | None" = None, clamp_bits=None) -> dict:
    """Phase-4 view of one tensor for ``_class_dispatch``."""
    starts = plan.start_bits
    ends = plan.end_bits
    if clamp_bits is not None:
        ends = jnp.minimum(ends, jnp.int32(clamp_bits))
    if bit_offset:
        starts = starts + jnp.int32(bit_offset)
        ends = ends + jnp.int32(bit_offset)
    seq_out_start = np.zeros(plan.seq_counts.shape[0] + 1, np.int64)
    seq_out_start[1:] = np.cumsum(plan.seq_counts)
    return {
        "starts": starts, "ends": ends,
        "counts_np": np.asarray(plan.counts),
        "sps": plan.subseqs_per_seq, "seq_counts": plan.seq_counts,
        "seq_out_start": seq_out_start, "classes": plan.classes,
        "lut_base": lut_base, "n_out": n_out,
    }


def decode(stream: EncodedStream, codebook, n_out: int, *,
           plan: "DecoderPlan | None" = None,
           backend: "str | DecodeBackend" = "ref",
           method: str = "gap", strategy: str = "tile",
           tile_syms: int = DEFAULT_TILE_SYMS,
           t_high: int = T_HIGH_DEFAULT,
           early_exit: bool = True,
           transform: "OutputTransform | None" = None) -> jnp.ndarray:
    """Decode one stream: the single entry point for every decoder variant.

    Args:
      stream:    the ``EncodedStream`` to decode.
      codebook:  anything with ``dec_sym`` / ``dec_len`` / ``max_len``
                 decode tables (normally a ``Codebook``).
      n_out:     number of symbols to emit.
      plan:      a prebuilt ``DecoderPlan`` (phases 1-3).  Plans are
                 backend-portable -- one built on "ref" executes exactly on
                 "pallas" and vice versa.  ``None`` builds one here with
                 ``method``.
      backend:   a registered backend name (``available_backends()``) or a
                 ``DecodeBackend`` handle.
      method:    sync discovery when building the plan: "gap" (gap array)
                 or "selfsync" (see ``VALID_PLAN_METHODS``).
      strategy:  decode-write variant: "tuned" (per-CR-class tiles, paper
                 Alg. 2), "tile" (fixed ``tile_syms`` tiles, Alg. 1), or
                 "padded" (the original decoders' baseline layout).
      tile_syms: tile size for the fixed-"tile" strategy.
      t_high:    highest non-overflow CR class when building the plan.
      early_exit: the self-sync ``__all_sync`` early-exit toggle.
      transform: optional ``OutputTransform``.  When attached, phase 4 runs
                 the backend's FUSED ops: the decoded symbols are carried
                 through dequantization and the inverse-Lorenzo prefix sum
                 inside the decode-write dispatch and the return value is
                 the reconstructed array, flat in C-order (the uint16
                 quant-code array is never materialized).  The transform's
                 ``shape`` picks the 1-D/2-D/3-D reconstruction and
                 ``out_dtype`` the output precision (f32 compute, one final
                 cast).  Supported for the "tile" and "padded" strategies
                 on backends registered with fused ops; the "tuned"
                 strategy gathers sequences by CR class, which reorders the
                 output and breaks the sequential reconstruction carry, so
                 it raises ``ValueError`` (callers such as
                 ``sz.compressor.decompress`` fall back to the two-pass
                 path and count ``stats["fused_fallbacks"]``).

    Returns uint16[n_out] quant codes, or reconstructed ``out_dtype[n_out]``
    when ``transform`` is attached.
    """
    be = get_backend(backend)
    luts = _as_luts(codebook)
    if plan is None:
        plan = build_plan(stream, codebook, method=method, backend=be,
                          t_high=t_high, early_exit=early_exit)
    units = jnp.asarray(stream.units)

    if transform is not None and strategy in ("tile", "padded"):
        if not be.supports_fused:
            raise ValueError(
                f"backend {be.name!r} registers no fused ops; check "
                f"backend.supports_fused before attaching a transform")
        t = transform
        t_shape = tuple(t.shape) if t.shape is not None else None
        t_dtype = (jnp.dtype(t.out_dtype) if t.out_dtype is not None
                   else jnp.float32)
        if strategy == "padded":
            return be.decode_padded_fused(
                units, luts.dec_sym, luts.dec_len, plan.start_bits,
                plan.end_bits, stream.total_bits, luts.max_len, n_out,
                t.outlier_pos, t.outlier_val, t.eb, t.radius,
                shape=t_shape, out_dtype=t_dtype)
        ss_max = ss_max_for_tile(tile_syms, luts.max_len)
        return be.decode_tiles_fused(
            units, luts.dec_sym, luts.dec_len, plan.start_bits,
            plan.end_bits, plan.offsets, stream.total_bits, luts.max_len,
            n_out, tile_syms, ss_max, t.outlier_pos, t.outlier_val, t.eb,
            t.radius, shape=t_shape, out_dtype=t_dtype)
    if transform is not None and strategy in VALID_STRATEGIES:
        raise ValueError(
            f"fused decode (transform=) supports strategies 'tile' and "
            f"'padded', not {strategy!r}: the tuned per-CR-class gather "
            f"reorders the output, which breaks the sequential Lorenzo "
            f"reconstruction carry")

    if strategy == "padded":
        return be.decode_padded(units, luts.dec_sym, luts.dec_len,
                                plan.start_bits, plan.end_bits,
                                stream.total_bits, luts.max_len, n_out)
    if strategy == "tile":
        ss_max = ss_max_for_tile(tile_syms, luts.max_len)
        return be.decode_tiles(units, luts.dec_sym, luts.dec_len,
                               plan.start_bits, plan.end_bits, plan.offsets,
                               stream.total_bits, luts.max_len, n_out,
                               tile_syms, ss_max)
    if strategy == "tuned":
        meta = _tensor_meta(plan, n_out)
        return _class_dispatch(be.decode_tiles, units, luts.dec_sym,
                               luts.dec_len, luts.max_len, stream.total_bits,
                               [meta], plan.t_high)[0]
    raise ValueError(f"unknown strategy {strategy!r}; valid strategies: "
                     f"{list(VALID_STRATEGIES)}")


def execute_tuned(stream: EncodedStream, dec_sym, dec_len, max_len: int,
                  n_out: int, start_bits, counts,
                  t_high: int = T_HIGH_DEFAULT, tiles_fn=None) -> jnp.ndarray:
    """Tuned per-class decode from precomputed phase 1-3 outputs.

    Raw-LUT entry point for callers that hold decode tables instead of a
    ``Codebook``: ``tiles_fn`` defaults to the jnp reference tile decoder
    and may be any ``decode_write_tiles``-shaped callable (e.g. the Pallas
    kernel wrapper).
    """
    if tiles_fn is None:
        tiles_fn = hd.decode_write_tiles
    counts = jnp.asarray(counts)
    sps = stream.subseqs_per_seq
    n_subseq = stream.n_subseq
    seq_counts = np.asarray(counts).reshape(-1, sps).sum(axis=1,
                                                         dtype=np.int64)
    classes = make_plan(None, seq_counts, sps, t_high)
    ends = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS + SUBSEQ_BITS
    plan = DecoderPlan(method="gap", start_bits=jnp.asarray(start_bits),
                       end_bits=ends, counts=counts,
                       offsets=hd.output_offsets(counts),
                       seq_counts=seq_counts, classes=classes,
                       subseqs_per_seq=sps, t_high=t_high)
    meta = _tensor_meta(plan, n_out)
    return _class_dispatch(tiles_fn, jnp.asarray(stream.units), dec_sym,
                           dec_len, max_len, stream.total_bits, [meta],
                           t_high)[0]


# ---------------------------------------------------------------------------
# Batched multi-tensor decode
# ---------------------------------------------------------------------------


def _merge_luts(codebooks) -> tuple:
    """Stack per-tensor decode LUTs into one table at a common ``max_len``.

    A tensor whose codebook peeks fewer bits than the global maximum gets
    its LUT upsampled: window ``w`` at ``max_len_g`` bits resolves via the
    top ``max_len_t`` bits, i.e. ``np.repeat`` by the width ratio.  Huffman
    codes are prefix-free, so the extra peeked bits never change the decoded
    (symbol, length) pair.
    """
    max_len_g = max(int(cb.max_len) for cb in codebooks)
    syms, lens, bases = [], [], []
    stride = 1 << max_len_g
    for t, cb in enumerate(codebooks):
        reps = 1 << (max_len_g - int(cb.max_len))
        syms.append(np.repeat(np.asarray(cb.dec_sym), reps))
        lens.append(np.repeat(np.asarray(cb.dec_len), reps))
        bases.append(t * stride)
    return (jnp.asarray(np.concatenate(syms)),
            jnp.asarray(np.concatenate(lens)), max_len_g, bases)


# Bit positions are int32 throughout the decode stack; keep every merged
# stream comfortably inside that space (one chunk still decode-batches
# hundreds of tensors -- 2^30 bits is 128 MiB of compressed payload).
MAX_BATCH_BITS = 1 << 30


def decode_batch(streams, codebooks, n_outs, *,
                 plans=None, backend: "str | DecodeBackend" = "ref",
                 method: str = "gap", t_high: int = T_HIGH_DEFAULT,
                 early_exit: bool = True) -> list:
    """Decode many tensors with one decode-write dispatch per CR class.

    Streams are concatenated at subsequence granularity (every stream is
    already padded to whole sequences), LUTs are merged at a common
    ``max_len`` with a per-subsequence ``lut_base``, and phase 4 gathers
    same-class sequences from ALL tensors into one tile-decode dispatch.
    Phases 1-3 remain per-tensor (they are the cheap, bandwidth-bound
    phases; the dispatch-bound phase is decode-write).

    Batches whose merged bitstream would overflow the int32 bit-position
    space are transparently split into sub-batches of at most
    ``MAX_BATCH_BITS`` merged bits (dispatch count then scales with the
    number of sub-batches, not with the tensor count).

    Returns a list of uint16 symbol arrays, bit-exact with per-tensor
    ``decode()``.  This entry point always emits quant codes; the fused
    decode→dequantize→reconstruct path is per-tensor by construction (its
    reconstruction carry follows one tensor's output order), so
    ``sz.compressor.decompress_batch(fused=True)`` routes eligible tensors
    through per-tensor fused decodes and only the remainder through this
    class-merged path.
    """
    items = list(zip(streams, codebooks, n_outs))
    if not items:
        return []
    be = get_backend(backend)
    if plans is None:
        plans = [build_plan(s, cb, method=method, backend=be, t_high=t_high,
                            early_exit=early_exit)
                 for s, cb, _ in items]

    # Split oversized multi-tensor batches.  A SINGLE stream over the budget
    # is never split (it is the base case): it decodes alone, subject to the
    # same int32 bit-position ceiling as every per-tensor decode.
    item_bits = [int(s.units.shape[0]) * UNIT_BITS for s in streams]
    if len(items) > 1 and sum(item_bits) > MAX_BATCH_BITS:
        outs, lo, acc = [], 0, 0
        for i, b in enumerate(item_bits):
            if acc and acc + b > MAX_BATCH_BITS:
                outs += decode_batch(streams[lo:i], codebooks[lo:i],
                                     n_outs[lo:i], plans=plans[lo:i],
                                     backend=be, t_high=t_high)
                lo, acc = i, 0
            acc += b
        outs += decode_batch(streams[lo:], codebooks[lo:], n_outs[lo:],
                             plans=plans[lo:], backend=be, t_high=t_high)
        return outs

    dec_sym, dec_len, max_len_g, lut_bases = _merge_luts(codebooks)

    unit_arrays = [jnp.asarray(s.units) for s in streams]
    units = jnp.concatenate(unit_arrays)
    bit_offsets = np.zeros(len(items), np.int64)
    bit_offsets[1:] = np.cumsum(
        [int(u.shape[0]) * UNIT_BITS for u in unit_arrays])[:-1]
    merged_total_bits = jnp.int32(int(units.shape[0]) * UNIT_BITS)

    metas = []
    for t, ((stream, _cb, n_out), plan) in enumerate(zip(items, plans)):
        # Windows must clamp at the *tensor's* payload end before shifting
        # into the merged bit space (the merged total no longer clamps them).
        metas.append(_tensor_meta(plan, n_out,
                                  bit_offset=int(bit_offsets[t]),
                                  lut_base=lut_bases[t],
                                  clamp_bits=stream.total_bits))
    return _class_dispatch(be.decode_tiles, units, dec_sym, dec_len,
                           max_len_g, merged_total_bits, metas, t_high)
