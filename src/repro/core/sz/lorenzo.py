"""Lorenzo prediction + error-bounded quantization (cuSZ's dual-quant).

cuSZ (Tian et al. 2020) breaks SZ's sequential predict-quantize loop with
*dual quantization*: the input is first rounded onto the uniform lattice
``2*eb`` (this is where the bounded error is introduced), and the Lorenzo
predictor then operates on exact lattice integers -- so prediction residuals
are exact and the whole transform is embarrassingly parallel in both
directions.  That property is what makes it a good TPU workload, and it is
the form the Pallas kernels implement.

  compress:    q  = round(x / (2*eb))               (lossy, |x - 2*eb*q| <= eb)
               d  = q - L(q)                         (Lorenzo residual, exact)
               code = clip(d + R, 0, 2R-1)           (uint16 bins, radius R)
               outliers: positions with |d| >= R keep d in a side list
  decompress:  d  = code - R  (outliers scattered back)
               q  = inclusive prefix-sum of d along every axis (inverse Lorenzo)
               x' = 2*eb * q

The N-d Lorenzo predictor is the inclusion-exclusion corner sum, whose exact
inverse is a chain of per-axis cumulative sums.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_RADIUS = 512  # 1024 quantization bins, cuSZ default


def _lorenzo_residual(q: jnp.ndarray) -> jnp.ndarray:
    """d = q - L(q) via alternating-sign finite differences along each axis."""
    d = q
    for axis in range(q.ndim):
        shifted = jnp.roll(d, 1, axis=axis)
        # zero boundary (predict 0 outside the domain)
        idx = [slice(None)] * q.ndim
        idx[axis] = slice(0, 1)
        shifted = shifted.at[tuple(idx)].set(0)
        d = d - shifted
    return d


def _lorenzo_reconstruct(d: jnp.ndarray) -> jnp.ndarray:
    """Inverse transform: inclusive cumsum along every axis."""
    q = d
    for axis in range(d.ndim):
        q = jnp.cumsum(q, axis=axis)
    return q


@partial(jax.jit, static_argnames=("radius",))
def quantize(x: jnp.ndarray, eb: float, radius: int = DEFAULT_RADIUS):
    """Returns (codes uint16, outlier_mask bool, residual int32).

    ``residual`` is the full-precision Lorenzo residual; callers keep only
    the masked entries as the outlier side list.

    Precision note: this is the in-graph (f32) path used by gradient / KV
    compression where ``eb`` is far above ulp scale.  When
    ``|x| / (2*eb) >= 2**23`` the f32 division can misplace lattice cells;
    the storage path (``compressor.compress``) therefore prequantizes
    host-side in float64 (:func:`quantize_host`).  Either way the
    reconstruction costs an extra ~ulp(|x|)/2 from the final f32 product --
    see ``Compressed.eb_effective``.
    """
    eb = jnp.asarray(eb, x.dtype)
    q = jnp.round(x / (2 * eb)).astype(jnp.int32)
    d = _lorenzo_residual(q)
    code = d + radius
    outlier = (code < 0) | (code >= 2 * radius)
    codes = jnp.clip(code, 0, 2 * radius - 1).astype(jnp.uint16)
    # In-range marker for outliers: code 0 is reserved (cuSZ convention);
    # the decoder overwrites those positions from the side list.
    codes = jnp.where(outlier, jnp.uint16(0), codes)
    return codes, outlier, d


def quantize_host(x, eb: float, radius: int = DEFAULT_RADIUS):
    """Float64 host-side prequantization (storage path).

    Returns (codes uint16[np], outlier_mask bool[np], residual int64[np]).
    Exact for ``|x| / (2*eb) < 2**62``; raises if the lattice index
    overflows int32 (which the int32 reconstruction path requires).
    """
    import numpy as np

    x64 = np.asarray(x, dtype=np.float64)
    q = np.round(x64 / (2.0 * eb))
    if np.abs(q).max(initial=0.0) >= 2**31 - 1:
        raise ValueError(
            "error bound too small for int32 lattice; increase eb")
    q = q.astype(np.int64)
    d = q.copy()
    for axis in range(q.ndim):
        shifted = np.roll(d, 1, axis=axis)
        idx = [slice(None)] * q.ndim
        idx[axis] = slice(0, 1)
        shifted[tuple(idx)] = 0
        d = d - shifted
    code = d + radius
    outlier = (code < 0) | (code >= 2 * radius)
    codes = np.clip(code, 0, 2 * radius - 1).astype(np.uint16)
    codes[outlier] = 0
    return codes, outlier, d


@partial(jax.jit, static_argnames=("radius", "shape", "dtype"))
def dequantize(codes: jnp.ndarray, outlier_pos: jnp.ndarray,
               outlier_val: jnp.ndarray, eb: float, shape: tuple,
               radius: int = DEFAULT_RADIUS, dtype=jnp.float32):
    """Inverse of :func:`quantize`.

    ``outlier_pos``/``outlier_val`` are flat positions and int32 residuals
    (padded with pos = -1 entries, which are dropped).

    The dequant product runs at ``promote_types(dtype, float32)`` precision
    with one final cast: float32/float64 outputs are computed natively
    (unchanged behavior), while low-precision outputs (bfloat16 / float16)
    are computed as ``q_f32 * 2*eb_f32`` and rounded ONCE at the end.  The
    fused kernels' epilogue performs the identical f32-multiply-then-cast,
    which is what keeps fused and two-pass bit-exact for every dtype.
    """
    d = codes.astype(jnp.int32) - radius
    flat = d.reshape(-1)
    # Padded entries carry pos == -1; route them out of bounds and drop.
    safe_pos = jnp.where(outlier_pos >= 0, outlier_pos, flat.shape[0])
    flat = flat.at[safe_pos].set(outlier_val.astype(jnp.int32), mode="drop")
    d = flat.reshape(shape)
    q = _lorenzo_reconstruct(d)
    compute = jnp.promote_types(jnp.dtype(dtype), jnp.float32)
    eb = jnp.asarray(eb, compute)
    return (q.astype(compute) * (2 * eb)).astype(dtype)
