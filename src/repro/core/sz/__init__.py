from repro.core.sz.compressor import Compressed, compress, decompress  # noqa: F401
