"""End-to-end SZ-style compressor: Lorenzo -> quantize -> Huffman.

This is the cuSZ pipeline the paper plugs its decoders into.  The compressor
is a host-orchestrated object (codebook construction is host-side numpy, see
``core/huffman/codebook.py``); the heavy encode/decode phases are jit'd jnp
or Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import codebook as cb
from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he
from repro.core.huffman import pipeline as hp
from repro.core.sz import lorenzo

DEFAULT_EB = 1e-3


@dataclasses.dataclass
class Compressed:
    """A compressed tensor (host container; fields are device arrays)."""

    stream: he.EncodedStream
    codebook: cb.Codebook
    outlier_pos: jnp.ndarray   # int32[m_pad], -1 padded
    outlier_val: jnp.ndarray   # int32[m_pad] Lorenzo residuals
    shape: tuple
    dtype: np.dtype
    eb: float
    radius: int
    rel_range: float           # value range used for relative error bounds
    max_abs: float = 0.0       # max |x|, for the effective-bound guarantee

    @property
    def n_symbols(self) -> int:
        return int(np.prod(self.shape))

    @property
    def compressed_bytes(self) -> int:
        """Storage accounting (paper's compression-ratio definition)."""
        unit_bytes = int(np.ceil(int(self.stream.total_bits) / 8))
        gap_bytes = self.stream.gaps.shape[0]  # 1 B / subsequence
        n_out = int((np.asarray(self.outlier_pos) >= 0).sum())
        outlier_bytes = 8 * n_out
        codebook_bytes = 2 * (1 << self.codebook.max_len)
        return unit_bytes + gap_bytes + outlier_bytes + codebook_bytes

    @property
    def original_bytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    @property
    def ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)

    @property
    def quant_code_bytes(self) -> int:
        """Size of the quantization-code array (paper computes decoder GB/s
        relative to this: 2 bytes per code)."""
        return 2 * self.n_symbols

    @property
    def eb_effective(self) -> float:
        """Guaranteed bound: eb + reconstruction rounding.

        The lattice value q is exact (float64 host prequantization); the
        further rounding is the f32 product ``q * 2*eb`` at reconstruction
        (one f32 ulp at max |x|), plus -- for low-precision outputs
        (bf16/f16) -- the single final cast of that product to the output
        dtype (half an output-dtype ulp at max |x'|).
        """
        bound = self.eb + float(np.spacing(np.float32(self.max_abs + self.eb)))
        dt = np.dtype(self.dtype)
        if dt.itemsize < 4:     # bf16/f16: one final-cast rounding step
            # jnp.finfo resolves ml_dtypes (bfloat16) where np.finfo cannot.
            bound += 0.5 * float(jnp.finfo(dt).eps) * (self.max_abs + bound)
        return bound


def _outlier_m_pad(n_out: int) -> int:
    """Power-of-two side-list padding; shared by host and device gather so
    identical logical payloads get identical padded layouts."""
    return max(8, int(2 ** np.ceil(np.log2(max(n_out, 1) + 1))))


@partial(jax.jit, static_argnames=("m_pad",))
def _gather_outliers(csum, resid_flat, m_pad: int):
    """Compact the outlier side list from an inclusive mask prefix sum.

    ``jnp.nonzero(size=...)`` lowers to a full-length scatter (serial on
    CPU, uncoalesced on accelerators); the k-th outlier's position is just
    ``searchsorted(csum, k + 1)`` -- ``m_pad`` binary searches and one
    gather, no scatter anywhere.  Ascending positions, -1/-0 padded, byte
    matching the host path's ``np.nonzero`` layout.
    """
    m = csum[-1]
    k = jnp.arange(1, m_pad + 1, dtype=jnp.int32)
    pos = jnp.searchsorted(csum, k, side="left").astype(jnp.int32)
    pos = jnp.where(k <= m, pos, -1)
    val = jnp.where(pos >= 0,
                    resid_flat[jnp.clip(pos, 0)].astype(jnp.int32), 0)
    return pos, val


def encode_unsupported_reason(x, backend) -> "str | None":
    """Why the device encode path cannot serve this tensor (None = it can).

    The in-graph quantizer is float32 (``lorenzo.quantize``); other dtypes
    fall back to the host path -- counted in
    ``stats["encode_fallbacks"]``, never wrong.
    """
    be = hp.get_encode_backend(backend)
    if not be.device:
        return f"backend {be.name!r} is the host path"
    if jnp.asarray(x).dtype != jnp.float32:
        return f"dtype {x.dtype} is not float32 (in-graph quantizer is f32)"
    return None


def compress(
    x,
    eb: float = DEFAULT_EB,
    mode: str = "rel",
    radius: int = lorenzo.DEFAULT_RADIUS,
    max_len: int = cb.DEFAULT_MAX_LEN,
    subseqs_per_seq: int = he.DEFAULT_SUBSEQS_PER_SEQ,
    encode_backend: str = "ref",
) -> Compressed:
    """Compress a float tensor with error bound ``eb``.

    mode="rel": bound is ``eb * (max(x) - min(x))`` (the paper's setting,
    "relative error bound 1e-3"); mode="abs": bound is ``eb`` directly.

    ``encode_backend`` selects the write-path pipeline
    (``pipeline.available_encode_backends()``): "ref" is the host path
    (float64 prequantization + numpy histogram); "jnp" / "pallas" run
    quantize -> outlier gather -> histogram -> bit-pack device-resident,
    with only the ``2*radius``-entry histogram crossing to host for
    codebook construction.  Device backends quantize in float32, so for
    eb far above ulp scale (the supported regime) the codes -- and
    therefore the emitted bytes -- match the host path; inputs a device
    backend cannot serve fall back to "ref", counted in
    ``stats["encode_fallbacks"]``.
    """
    x = jnp.asarray(x)
    if mode == "rel":
        rng = float(jnp.max(x) - jnp.min(x))
        rng = rng if rng > 0 else 1.0
        abs_eb = eb * rng
    elif mode == "abs":
        rng = 1.0
        abs_eb = eb
    else:
        raise ValueError(f"unknown mode {mode!r}")
    max_abs = float(jnp.max(jnp.abs(x)))

    ebe = hp.get_encode_backend(encode_backend)
    if ebe.device and encode_unsupported_reason(x, ebe) is not None:
        ebe.bump("encode_fallbacks")
        ebe = hp.get_encode_backend("ref")

    if ebe.device:
        # Same int32-lattice guard the host prequantizer raises.
        if np.round(max_abs / (2.0 * abs_eb)) >= 2**31 - 1:
            raise ValueError(
                "error bound too small for int32 lattice; increase eb")
        codes, outlier, resid = ebe.quantize_fn(x, abs_eb, radius)
        codes_flat = codes.reshape(-1)
        csum = jnp.cumsum(outlier.reshape(-1).astype(jnp.int32))
        # One scalar sync sizes the side list; the gather stays on device.
        m_pad = _outlier_m_pad(int(csum[-1]))
        pos_pad, val_pad = _gather_outliers(csum, resid.reshape(-1), m_pad)
        freq = ebe.hist_fn(codes_flat, 2 * radius)
    else:
        codes_np, outlier, resid = ebe.quantize_fn(x, abs_eb, radius)
        codes_flat = codes_np.reshape(-1)

        # Outlier side list (exact residuals), padded to power-of-two length.
        pos = np.nonzero(np.asarray(outlier).reshape(-1))[0].astype(np.int32)
        vals = np.asarray(resid).reshape(-1)[pos].astype(np.int32)
        m_pad = _outlier_m_pad(len(pos))
        pos_pad = np.full(m_pad, -1, np.int32)
        val_pad = np.zeros(m_pad, np.int32)
        pos_pad[: len(pos)] = pos
        val_pad[: len(pos)] = vals
        freq = ebe.hist_fn(codes_flat, 2 * radius)

    # Histogram -> codebook (host package-merge) -> bit-pack dispatch.
    plan = hp.build_encoder_plan(freq, max_len=max_len,
                                 subseqs_per_seq=subseqs_per_seq, backend=ebe)
    stream = hp.encode_with_plan(codes_flat, plan, backend=ebe)

    return Compressed(
        stream=stream,
        codebook=plan.codebook,
        outlier_pos=jnp.asarray(pos_pad),
        outlier_val=jnp.asarray(val_pad),
        shape=tuple(x.shape),
        dtype=np.dtype(str(x.dtype)),
        eb=abs_eb,
        radius=radius,
        rel_range=rng,
        max_abs=max_abs,
    )


def _dequantize(c: Compressed, codes: jnp.ndarray) -> jnp.ndarray:
    return lorenzo.dequantize(
        codes.reshape(c.shape), c.outlier_pos, c.outlier_val, c.eb, c.shape,
        radius=c.radius, dtype=jnp.dtype(str(c.dtype)))


def _fused_transform(c: Compressed) -> hp.OutputTransform:
    return hp.OutputTransform(eb=c.eb, radius=c.radius,
                              outlier_pos=c.outlier_pos,
                              outlier_val=c.outlier_val,
                              shape=tuple(c.shape),
                              out_dtype=jnp.dtype(str(np.dtype(c.dtype))))


#: Output dtypes the fused epilogue serves (f32 compute, one final cast).
FUSED_DTYPES = ("float32", "bfloat16", "float16")
#: Widest fastest axis the row-tiled N-D epilogue provisions for (one tile
#: must hold at least one whole row in VMEM).
FUSED_MAX_COLS = 1 << 15
#: Largest 3-D plane (rows * cols) the VMEM plane-carry scratch can hold.
FUSED_MAX_PLANE = 1 << 20


def fused_unsupported_reason(c: Compressed, backend, method: str,
                             strategy: str) -> "str | None":
    """Why the fused decode path cannot serve this tensor (None = it can).

    The fused epilogue covers 1-D/2-D/3-D inverse Lorenzo (unit axes are
    squeezed first -- ``kernels/ops.py:fused_squeeze``) over float32,
    bfloat16 and float16 outputs (``FUSED_DTYPES``).  Still falling back
    to the two-pass path (recorded in ``stats["fused_fallbacks"]``):
    >3-D tensors, other dtypes, rows wider than ``FUSED_MAX_COLS``,
    3-D planes larger than ``FUSED_MAX_PLANE`` (the VMEM plane-carry
    bound), the sequential oracle method, the class-gathering "tuned"
    strategy, and backends registered without fused ops.
    """
    be = hp.get_backend(backend)
    if method == "naive_ref":
        return "method 'naive_ref' is the sequential oracle"
    if strategy not in ("tile", "padded"):
        return ("strategy 'tuned' gathers sequences by CR class, which "
                "breaks the sequential reconstruction carry")
    if not be.supports_fused:
        return f"backend {be.name!r} registers no fused ops"
    if np.dtype(c.dtype).name not in FUSED_DTYPES:
        return (f"dtype {np.dtype(c.dtype)} not in fused set "
                f"{FUSED_DTYPES}")
    sq = tuple(s for s in c.shape if s != 1)
    if len(sq) > 3:
        return (f"{len(sq)}-D Lorenzo reconstruction (fused epilogue "
                f"covers up to 3-D)")
    if len(sq) >= 2 and sq[-1] > FUSED_MAX_COLS:
        return (f"fastest axis {sq[-1]} exceeds the per-tile row bound "
                f"{FUSED_MAX_COLS}")
    if len(sq) == 3 and sq[-2] * sq[-1] > FUSED_MAX_PLANE:
        return (f"plane {sq[-2]}x{sq[-1]} exceeds the VMEM plane-carry "
                f"bound {FUSED_MAX_PLANE}")
    return None


def _guard_symbol_count(c: Compressed, plan, backend) -> None:
    """Decoder guard: a plan must decode exactly ``c.n_symbols`` symbols.

    The per-subsequence counts of a corrupt (CRC-valid-but-malformed in
    memory) stream can disagree with the tensor's recorded shape; decoding
    would then scatter a wrong number of symbols into plausible-looking
    output.  Detect it here -- where ``n_symbols == prod(shape)`` is an
    invariant -- rather than in ``pipeline.decode``, whose callers may
    legitimately decode a prefix.  Trips count in
    ``stats["decode_guard_trips"]`` and raise ``DecodeGuardError``.
    """
    if plan is None:
        return
    total = int(np.asarray(plan.seq_counts).sum())
    if total != c.n_symbols:
        hp.get_backend(backend).bump("decode_guard_trips")
        raise hp.DecodeGuardError(
            f"symbol-count mismatch: plan decodes {total} symbols but the "
            f"tensor records n_symbols={c.n_symbols} (shape "
            f"{tuple(c.shape)}) -- corrupt stream metadata")


def decompress(
    c: Compressed,
    method: str = "gap",
    tile_syms: int = hp.DEFAULT_TILE_SYMS,
    *,
    backend: "str | hp.DecodeBackend" = "ref",
    strategy: str = "tile",
    t_high: int = hp.T_HIGH_DEFAULT,
    plan=None,
    fused: bool = False,
) -> jnp.ndarray:
    """Decompress; ``method`` in {"gap", "selfsync", "naive_ref"}.

    This is the raw engine function: every knob is a per-call argument.
    Application code should normally hold a configured ``repro.core.Codec``
    (which adds plan caching and a fixed policy) instead of calling this
    directly.  Decoding goes through ``core.huffman.pipeline.decode``:
    ``backend`` in ``available_backends()`` selects the jnp reference or the
    Pallas kernels (interpret mode on CPU), ``strategy`` in {"tuned", "tile",
    "padded"} selects the decode-write variant, and ``plan`` may carry a
    prebuilt ``DecoderPlan``.

    ``fused=True`` requests the fused decode→dequantize→reconstruct path:
    phase 4 carries the decoded symbols straight through dequantization and
    the inverse-Lorenzo prefix sum inside the decode-write dispatch, never
    materializing the uint16 quant-code array.  Output is bit-exact with
    the two-pass path.  When the request cannot be served (see
    :func:`fused_unsupported_reason`) it silently falls back to two-pass
    decoding and increments ``backend.stats["fused_fallbacks"]``.
    """
    book = c.codebook
    n = c.n_symbols

    if plan is None and method in hp.VALID_PLAN_METHODS:
        plan = hp.build_plan(c.stream, book, method=method, backend=backend,
                             t_high=t_high)
    _guard_symbol_count(c, plan, backend)

    if fused:
        reason = fused_unsupported_reason(c, backend, method, strategy)
        if reason is None:
            out = hp.decode(c.stream, book, n, plan=plan, method=method,
                            backend=backend, strategy=strategy,
                            tile_syms=tile_syms, t_high=t_high,
                            transform=_fused_transform(c))
            return out.reshape(c.shape)
        hp.get_backend(backend).bump("fused_fallbacks")

    if method == "naive_ref":
        codes = hd.decode_sequential(jnp.asarray(c.stream.units),
                                     jnp.asarray(book.dec_sym),
                                     jnp.asarray(book.dec_len), n_symbols=n,
                                     max_len=book.max_len)
    else:
        codes = hp.decode(c.stream, book, n, plan=plan, method=method,
                          backend=backend, strategy=strategy,
                          tile_syms=tile_syms, t_high=t_high)
    return _dequantize(c, codes)


def decompress_batch(
    cs: "list[Compressed]",
    method: str = "gap",
    *,
    backend: str = "ref",
    strategy: str = "tile",
    t_high: int = hp.T_HIGH_DEFAULT,
    plans: "list | None" = None,
    fused: bool = False,
) -> list:
    """Decompress many tensors with class-batched decode dispatch.

    Huffman decode-write runs once per CR class across ALL tensors
    (``pipeline.decode_batch``) instead of once per class per tensor --
    the dispatch structure that makes restoring N checkpoint shards or
    KV-cache blocks scale with class count, not tensor count.  Output is
    bit-exact with per-tensor ``decompress``.  ``plans`` may carry prebuilt
    (e.g. cached) ``DecoderPlan`` objects, one per tensor, in which case the
    phase 1-3 rebuild is skipped entirely (the store's plan cache rides on
    this).

    ``fused=True`` trades dispatch merging for intermediate traffic:
    tensors the fused path can serve (see :func:`fused_unsupported_reason`)
    decode one-by-one through the fused kernels under ``strategy`` (zero
    quant-code HBM round trip, but one dispatch chain per tensor); the
    rest decode through the class-merged two-pass path.  Eligibility is
    evaluated exactly ONCE per tensor here -- against the strategy that
    would actually run -- and every ineligible tensor bumps
    ``stats["fused_fallbacks"]`` exactly once.  Output order and bit
    patterns are unchanged either way.
    """
    if not cs:
        return []
    if plans is None and method in hp.VALID_PLAN_METHODS:
        plans = [hp.build_plan(c.stream, c.codebook, method=method,
                               backend=backend, t_high=t_high) for c in cs]
    if plans is not None:
        for c, p in zip(cs, plans):
            _guard_symbol_count(c, p, backend)
    if fused:
        outs: list = [None] * len(cs)
        rest = []
        be = hp.get_backend(backend)
        for i, c in enumerate(cs):
            if fused_unsupported_reason(c, be, method, strategy) is None:
                out = hp.decode(c.stream, c.codebook, c.n_symbols,
                                plan=plans[i] if plans else None,
                                method=method, backend=be,
                                strategy=strategy, t_high=t_high,
                                transform=_fused_transform(c))
                outs[i] = out.reshape(c.shape)
            else:
                be.bump("fused_fallbacks")
                rest.append(i)
        if rest:
            codes = hp.decode_batch(
                [cs[i].stream for i in rest], [cs[i].codebook for i in rest],
                [cs[i].n_symbols for i in rest], method=method, backend=be,
                t_high=t_high,
                plans=[plans[i] for i in rest] if plans else None)
            for i, q in zip(rest, codes):
                outs[i] = _dequantize(cs[i], q)
        return outs
    codes = hp.decode_batch([c.stream for c in cs], [c.codebook for c in cs],
                            [c.n_symbols for c in cs], method=method,
                            backend=backend, t_high=t_high, plans=plans)
    return [_dequantize(c, q) for c, q in zip(cs, codes)]
