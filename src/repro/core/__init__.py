# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

from repro.core.cache import DEFAULT_PLAN_CACHE, PlanCache  # noqa: F401
from repro.core.codec import Codec, CodecConfig, default_codec  # noqa: F401
