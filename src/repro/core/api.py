"""Public compression API used by the framework features.

Three consumers (see DESIGN.md §2):
  * checkpoint/manager.py  -- compressed checkpoint shards
  * models/kvcache.py      -- compressed KV-cache blocks
  * optim/grad_compress.py -- gradient compression (uses quantize only;
                              entropy stage is storage-side)
"""

from __future__ import annotations

from repro.core.sz.compressor import (  # noqa: F401  (public re-exports)
    Compressed,
    compress,
    decompress,
)
from repro.core.sz import lorenzo  # noqa: F401


def roundtrip_error(x, c: "Compressed", xhat) -> float:
    """Max abs error of a round trip (must be <= c.eb)."""
    import numpy as np

    return float(np.max(np.abs(np.asarray(x) - np.asarray(xhat))))
