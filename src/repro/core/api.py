"""Public compression API used by the framework features.

Framework consumers ride on this module (see README.md for the
architecture of the plan/execute decode stack):
  * repro/store            -- chunked ``.szt`` archives; the reader decodes
                              chunk groups through ``decompress_batch`` with
                              cached plans and prefetched reads
  * checkpoint/manager.py  -- compressed checkpoint shards, one store
                              archive per step
  * models/kvcache.py      -- compressed KV-cache blocks, batch-decoded and
                              pageable via ``repro.store.KVPager``

Decoding is served by ``repro.core.huffman.pipeline``: ``build_plan`` runs
the sync/count/prefix-sum phases and CR classification, ``decode`` executes
the plan on a registered backend ("ref" jnp or "pallas" kernels), and
``decode_batch`` merges the per-CR-class decode dispatch across tensors.
"""

from __future__ import annotations

from repro.core.huffman.pipeline import (  # noqa: F401  (public re-exports)
    DecodeBackend,
    DecoderPlan,
    available_backends,
    build_plan,
    decode,
    decode_batch,
    get_backend,
    register_backend,
)
from repro.core.sz.compressor import (  # noqa: F401  (public re-exports)
    Compressed,
    compress,
    decompress,
    decompress_batch,
)
from repro.core.sz import lorenzo  # noqa: F401


def roundtrip_error(x, c: "Compressed", xhat) -> float:
    """Max abs error of a round trip (must be <= c.eb)."""
    import numpy as np

    return float(np.max(np.abs(np.asarray(x) - np.asarray(xhat))))
