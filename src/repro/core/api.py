"""Public compression API used by the framework features.

Two framework consumers ride on this module (see README.md for the
architecture of the plan/execute decode stack):
  * checkpoint/manager.py  -- compressed checkpoint shards; restore decodes
                              all shards through ``decompress_batch``
  * models/kvcache.py      -- compressed KV-cache blocks, also batch-decoded

Decoding is served by ``repro.core.huffman.pipeline``: ``build_plan`` runs
the sync/count/prefix-sum phases and CR classification, ``decode`` executes
the plan on a registered backend ("ref" jnp or "pallas" kernels), and
``decode_batch`` merges the per-CR-class decode dispatch across tensors.
"""

from __future__ import annotations

from repro.core.huffman.pipeline import (  # noqa: F401  (public re-exports)
    DecodeBackend,
    DecoderPlan,
    available_backends,
    build_plan,
    decode,
    decode_batch,
    get_backend,
    register_backend,
)
from repro.core.sz.compressor import (  # noqa: F401  (public re-exports)
    Compressed,
    compress,
    decompress,
    decompress_batch,
)
from repro.core.sz import lorenzo  # noqa: F401


def roundtrip_error(x, c: "Compressed", xhat) -> float:
    """Max abs error of a round trip (must be <= c.eb)."""
    import numpy as np

    return float(np.max(np.abs(np.asarray(x) - np.asarray(xhat))))
