"""Public compression API: the Codec session is the single entry point.

A ``Codec`` binds a frozen ``CodecConfig`` (error bound + bound mode on the
quantizer side; sync method, decode strategy, backend, and tuner ``t_high``
on the decoder side) to a backend handle and a digest-keyed ``PlanCache``:

    from repro.core.api import Codec, CodecConfig

    codec = Codec(CodecConfig(eb=1e-4, backend="pallas", strategy="tuned"))
    c = codec.compress(x)
    xhat = codec.decompress(c)                 # phase 1-3 plan cached
    tree = codec.compress_tree(params)         # pytree of Compressed leaves
    back = codec.decompress_tree(tree)         # ONE dispatch per CR class

Every framework consumer rides on a Codec (see README.md for the
architecture of the plan/execute decode stack):
  * repro/store            -- ``Archive`` / ``KVPager`` take ``codec=``;
                              chunk digests key the codec's plan cache, so
                              a warm open rebuilds zero plans
  * checkpoint/manager.py  -- ``CheckpointManager(dir, codec=...)``; the
                              codec's eb/mode compresses the shards and its
                              plan cache makes re-restores phase-4 only
  * models/kvcache.py      -- ``compress_cache(cache, codec=...)`` /
                              ``decompress_cache`` over ``compress_tree``
  * launch/serve.py        -- one ``--kv-eb``/``--kv-backend``-built Codec
                              drives both KV offload paging and in-memory
                              cache compression

The module-level ``compress`` / ``decompress`` / ``decompress_batch``
functions are thin shims over a default Codec (kept for one-off library
use); the legacy ``use_tiles`` / ``use_kernels`` / ``tuned`` flags raise
``TypeError`` pointing at ``CodecConfig`` (migration table in docs/api.md).

Decoding is served by ``repro.core.huffman.pipeline``: ``build_plan`` runs
the sync/count/prefix-sum phases and CR classification, ``decode`` executes
the plan on a registered backend ("ref" jnp or "pallas" kernels), and
``decode_batch`` merges the per-CR-class decode dispatch across tensors.
"""

from __future__ import annotations

from repro.core.cache import (  # noqa: F401  (public re-exports)
    DEFAULT_PLAN_CACHE,
    PlanCache,
    compressed_digest,
)
from repro.core.codec import (  # noqa: F401  (public re-exports)
    Codec,
    CodecConfig,
    compress,
    decompress,
    decompress_batch,
    default_codec,
)
from repro.core.huffman.pipeline import (  # noqa: F401  (public re-exports)
    DecodeBackend,
    DecoderPlan,
    available_backends,
    build_plan,
    decode,
    decode_batch,
    get_backend,
    register_backend,
)
from repro.core.sz.compressor import Compressed  # noqa: F401
from repro.core.sz import lorenzo  # noqa: F401


def roundtrip_error(x, c: "Compressed", xhat) -> float:
    """Max abs error of a round trip (must be <= c.eb)."""
    import numpy as np

    return float(np.max(np.abs(np.asarray(x) - np.asarray(xhat))))
