"""mmap-backed archive reader with double-buffered read + decode.

Opening an archive is two small reads (header, index) over an ``mmap``;
chunk payloads are zero-copy ``np.frombuffer`` views into the map, so the
host never materializes the archive twice.  Every read validates the
chunk's CRC32 before the bytes reach the decoder, turning silent disk /
transfer corruption into a ``StoreCorruptError`` that names the tensor.

The batched read path (``iter_decode`` / ``read_all``) is the store's
performance surface: chunks are decoded in groups through
``decompress_batch`` (one decode-write dispatch per CR class per group),
while a single prefetch thread reads + CRC-validates group N+1 from disk
as the device decodes group N -- the classic double buffer, so cold-cache
restore time approaches max(I/O, decode) instead of their sum.  Phase 1-3
plans come from the ``PlanCache`` keyed by chunk digest; a warm cache
(serving restart, repeated KV page-in) rebuilds zero plans, observable via
``DecodeBackend.stats["plan_builds"]``.
"""

from __future__ import annotations

import concurrent.futures as futures
import mmap
import os

import jax.numpy as jnp
import numpy as np

from repro.core.cache import PlanCache
from repro.core.codec import Codec, default_codec
from repro.core.huffman import codebook as cb
from repro.core.huffman import pipeline as hp
from repro.core.huffman.encode import EncodedStream
from repro.core.sz import compressor as sz
from repro.runtime import fault_tolerance as ft
from repro.store import format as F

DEFAULT_GROUP_CHUNKS = 8


def _build_codebook(rec: F.CodebookRecord, enc_code, enc_len) -> cb.Codebook:
    dec_sym, dec_len = cb.build_decode_lut(enc_code, enc_len, rec.max_len)
    return cb.Codebook(n_symbols=rec.n_symbols, max_len=rec.max_len,
                       enc_code=np.array(enc_code),
                       enc_len=np.array(enc_len),
                       dec_sym=dec_sym, dec_len=dec_len)


class Archive:
    """One open ``.szt`` archive (use as a context manager)."""

    def __init__(self, path: str, *, codec: "Codec | None" = None,
                 plan_cache: "PlanCache | None" = None):
        self.path = path
        self.codec = codec if codec is not None else default_codec()
        self.cache = (self.codec.plan_cache if plan_cache is None
                      else plan_cache)
        #: Degradation counters: chunks dropped / zeroed by a non-raise
        #: recovery policy, and transient-IO retries spent on this archive.
        self.stats = {"chunks_skipped": 0, "chunks_zero_filled": 0,
                      "io_retries": 0}
        # Transient IO errors (OSError) while opening retry per the codec's
        # recovery policy; corruption (StoreError) never retries.
        ft.with_retries(self._open, self.codec.recovery_policy(),
                        on_retry=self._count_retry)

    def _count_retry(self, attempt, exc):
        self.stats["io_retries"] += 1

    def _open(self):
        path = self.path
        size = os.path.getsize(path)
        self._f = open(path, "rb")
        try:
            if size < F.HEADER_SIZE:
                raise F.StoreCorruptError(
                    f"{path}: truncated archive ({size} bytes)")
            self._mm = mmap.mmap(self._f.fileno(), 0, access=mmap.ACCESS_READ)
            head = F.unpack_header(self._mm[:F.HEADER_SIZE])
            lo, n = head["index_off"], head["index_len"]
            if lo + n > size:
                raise F.StoreCorruptError(
                    f"{path}: truncated archive (index extends to byte "
                    f"{lo + n} of a {size}-byte file)")
            index = self._mm[lo:lo + n]
            if F.crc32_arrays(np.frombuffer(index, np.uint8)) != \
                    head["index_crc"]:
                raise F.StoreCorruptError(f"{path}: index checksum mismatch")
            self._codebooks, chunks = F.unpack_index(index)
            self._cb_by_digest = {c.digest: c for c in self._codebooks}
            self._chunks = {c.name: c for c in chunks}
            if len(self._chunks) != head["n_chunks"]:
                raise F.StoreCorruptError(
                    f"{path}: header declares {head['n_chunks']} chunks, "
                    f"index holds {len(self._chunks)}")
        except BaseException:
            self._f.close()
            raise

    # -- introspection ------------------------------------------------------

    @property
    def names(self) -> list:
        return list(self._chunks)

    def __len__(self):
        return len(self._chunks)

    def __contains__(self, name):
        return name in self._chunks

    def chunk(self, name: str) -> F.ChunkRecord:
        try:
            return self._chunks[name]
        except KeyError:
            raise KeyError(f"{self.path}: no chunk named {name!r}") from None

    @property
    def n_codebooks(self) -> int:
        return len(self._codebooks)

    # -- raw access ---------------------------------------------------------

    def _blob(self, ref: F.BlobRef, dtype) -> np.ndarray:
        if ref.offset + ref.length > len(self._mm):
            raise F.StoreCorruptError(
                f"{self.path}: blob at {ref.offset}+{ref.length} extends "
                f"past end of file")
        return np.frombuffer(self._mm, dtype=dtype, count=ref.length
                             // np.dtype(dtype).itemsize, offset=ref.offset)

    def codebook(self, digest: str) -> cb.Codebook:
        rec = self._cb_by_digest[digest]

        def build():
            enc_code = self._blob(rec.enc_code, np.uint32)
            enc_len = self._blob(rec.enc_len, np.uint8)
            if F.crc32_arrays(enc_code, enc_len) != rec.crc32:
                raise F.StoreCorruptError(
                    f"{self.path}: codebook {digest[:12]} checksum mismatch")
            return _build_codebook(rec, enc_code, enc_len)

        return self.cache.get_codebook(digest, build)

    def read_chunk(self, name: str, validate: bool = True):
        """Read (and optionally CRC-check) one chunk into a ``Compressed``.

        Host-side only -- this is the half the prefetch thread runs.
        """
        rec = self.chunk(name)
        units = self._blob(rec.units, np.uint32)
        gaps = self._blob(rec.gaps, np.uint8)
        opos = self._blob(rec.outlier_pos, np.int32)
        oval = self._blob(rec.outlier_val, np.int32)
        if validate and F.crc32_arrays(units, gaps, opos, oval) != rec.crc32:
            raise F.StoreCorruptError(
                f"{self.path}: chunk {name!r} payload checksum mismatch "
                f"(corrupt or truncated archive)")
        # Copy out of the map before device placement: on the CPU backend
        # jax aliases numpy buffers zero-copy, which would pin the mmap (and
        # the archive file) for the lifetime of the decoded tensors.
        units, gaps = np.array(units), np.array(gaps)
        opos, oval = np.array(opos), np.array(oval)
        book = self.codebook(rec.codebook)
        n_subseq = gaps.shape[0]
        stream = EncodedStream(
            units=jnp.asarray(units), gaps=jnp.asarray(gaps),
            # Ground-truth counts are not stored: the decoder recomputes
            # them on device in phase 1 (or loads a cached plan).
            counts=jnp.zeros((n_subseq,), jnp.int32),
            seq_counts=jnp.zeros((n_subseq // rec.subseqs_per_seq,),
                                 jnp.int32),
            total_bits=jnp.asarray(rec.total_bits, jnp.int32),
            n_symbols=jnp.asarray(rec.n_symbols, jnp.int32),
            subseqs_per_seq=rec.subseqs_per_seq)
        c = sz.Compressed(
            stream=stream, codebook=book,
            outlier_pos=jnp.asarray(opos), outlier_val=jnp.asarray(oval),
            shape=rec.shape, dtype=np.dtype(rec.dtype), eb=rec.eb,
            radius=rec.radius, rel_range=rec.rel_range, max_abs=rec.max_abs)
        # Seed the content digest from the index record so a direct
        # ``Codec.decompress`` of this tensor shares the archive's
        # plan-cache entries without re-hashing the payload.
        c._digest = rec.digest
        return c

    # -- decoded access -----------------------------------------------------

    def _plan_for(self, rec: F.ChunkRecord, c, method: str, t_high: int,
                  backend):
        key = (rec.digest, method, t_high)
        return self.cache.get_or_build_plan(
            key, lambda: hp.build_plan(c.stream, c.codebook, method=method,
                                       backend=backend, t_high=t_high))

    def _recover(self, name: str, exc, pol, on_error):
        """Apply the recovery policy to one failed chunk.

        Returns the substitute array (``zero_fill``), ``None`` (``skip``,
        counted), or raises the named error (``raise``).
        """
        if on_error is not None:
            on_error(name, exc)
        if pol.on_error == "raise":
            raise exc
        if pol.on_error == "zero_fill":
            rec = self._chunks.get(name)
            if rec is not None:
                self.stats["chunks_zero_filled"] += 1
                return jnp.zeros(rec.shape, jnp.dtype(rec.orig_dtype))
        self.stats["chunks_skipped"] += 1
        return None

    def iter_decode(self, names=None, *, group_chunks: int =
                    DEFAULT_GROUP_CHUNKS, method: "str | None" = None,
                    backend: "str | None" = None, t_high: "int | None" = None,
                    fused: "bool | None" = None, validate: bool = True,
                    prefetch: bool = True, policy=None, on_error=None,
                    as_numpy: bool = False):
        """Yield ``(name, decoded array)`` with I/O overlapped against decode.

        Chunks stream in groups of ``group_chunks``: each group decodes as
        one ``decompress_batch`` call while the prefetch thread reads and
        CRC-validates the next group.  Decoded tensors stay on device, cast
        to each chunk's recorded ``orig_dtype``.  Decode policy (sync
        method, backend, tuner ``t_high``, the ``fused``
        decode→dequantize→reconstruct dispatch) defaults to the archive's
        codec; the keyword overrides exist for benchmarking alternates.

        Failure handling (docs/robustness.md): the prefetch thread captures
        per-chunk errors and hands them to the consumer loop, so an
        exception in group N+1's read/validate deterministically reaches
        the caller instead of killing the thread.  ``policy`` (a string or
        ``RecoveryPolicy``; default: the codec's ``recovery`` config)
        decides what happens per failed chunk: ``"raise"`` propagates the
        named error, ``"skip"`` omits the entry (counted in
        ``stats["chunks_skipped"]``), ``"zero_fill"`` yields zeros of the
        recorded shape/dtype (``stats["chunks_zero_filled"]``).  Transient
        ``OSError`` reads retry with backoff first (``stats["io_retries"]``).
        ``on_error(name, exc)`` is invoked for every failed chunk before
        the policy applies.

        ``as_numpy`` yields host ``np.ndarray`` values instead of device
        arrays -- the shard-restore path assembles per-device tiles on the
        host before placing them, so pinning decoded tiles to the default
        device would be a wasted hop.
        """
        cfg = self.codec.config
        method = cfg.method if method is None else method
        t_high = cfg.t_high if t_high is None else t_high
        fused = cfg.fused if fused is None else fused
        be = (self.codec.backend if backend is None
              else hp.get_backend(backend))
        pol = self.codec.recovery_policy(policy)
        names = self.names if names is None else list(names)
        groups = [names[i:i + group_chunks]
                  for i in range(0, len(names), group_chunks)]
        if not groups:
            return

        def load_one(name):
            return ft.with_retries(
                lambda: self.read_chunk(name, validate=validate), pol,
                on_retry=self._count_retry)

        def load(group):
            # Per-chunk outcomes (Compressed or the exception), NOT a raise:
            # raising here would kill the prefetch thread and lose the
            # error; the consumer loop applies the recovery policy instead.
            out = []
            for n in group:
                try:
                    out.append(load_one(n))
                except F.StoreError as e:
                    out.append(e)
                except OSError as e:
                    err = F.StoreIOError(
                        f"{self.path}: reading chunk {n!r} failed after "
                        f"{pol.retries} retries: {e}")
                    err.__cause__ = e
                    out.append(err)
            return out

        pool = (futures.ThreadPoolExecutor(
            1, thread_name_prefix="szt-prefetch")
            if prefetch and len(groups) > 1 else None)
        try:
            nxt = pool.submit(load, groups[0]) if pool else None
            for gi, group in enumerate(groups):
                blobs = nxt.result() if pool else load(group)
                if pool and gi + 1 < len(groups):
                    nxt = pool.submit(load, groups[gi + 1])

                failed = {}                      # name -> named exception
                ok_names, ok_cs, ok_plans = [], [], []
                for n, c in zip(group, blobs):
                    if isinstance(c, Exception):
                        failed[n] = c
                        continue
                    try:
                        plan = self._plan_for(self.chunk(n), c, method,
                                              t_high, be)
                    except hp.DecodeGuardError as e:
                        failed[n] = e
                        continue
                    ok_names.append(n)
                    ok_cs.append(c)
                    ok_plans.append(plan)

                outs = {}
                if ok_cs:
                    try:
                        decoded = sz.decompress_batch(
                            ok_cs, method=method, backend=be,
                            strategy=cfg.strategy, t_high=t_high,
                            plans=ok_plans, fused=fused)
                        outs = dict(zip(ok_names, decoded))
                    except hp.DecodeGuardError:
                        # Salvage the group chunk-by-chunk so one malformed
                        # stream cannot take down its batch-mates.
                        for n, c, p in zip(ok_names, ok_cs, ok_plans):
                            try:
                                outs[n] = sz.decompress(
                                    c, method=method, backend=be,
                                    strategy=cfg.strategy, t_high=t_high,
                                    plan=p, fused=fused)
                            except hp.DecodeGuardError as e:
                                failed[n] = e

                for name in group:
                    if name in outs:
                        out = jnp.asarray(
                            outs[name],
                            jnp.dtype(self.chunk(name).orig_dtype))
                        yield name, np.asarray(out) if as_numpy else out
                        continue
                    sub = self._recover(name, failed[name], pol, on_error)
                    if sub is not None:
                        yield name, np.asarray(sub) if as_numpy else sub
        finally:
            if pool:
                pool.shutdown(wait=False, cancel_futures=True)

    def read_all(self, names=None, **kwargs) -> dict:
        """Decode ``names`` (default: every chunk) into {name: array}."""
        return dict(self.iter_decode(names, **kwargs))

    def read_tensor(self, name: str, **kwargs):
        return self.read_all([name], **kwargs)[name]

    # -- lifecycle ----------------------------------------------------------

    def close(self):
        if getattr(self, "_mm", None) is not None:
            try:
                self._mm.close()
            except BufferError:
                # A caller still holds a zero-copy view (e.g. a raw _blob);
                # the map stays alive until the last view dies, which is
                # safe for an ACCESS_READ mapping.
                pass
            self._mm = None
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def open_archive(path: str, **kwargs) -> Archive:
    return Archive(path, **kwargs)
