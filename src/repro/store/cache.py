"""Plan / LUT cache: make the second open of an archive metadata-free.

Two maps, both keyed by content digests from ``store.format``:

* **codebooks** -- codebook digest -> materialized ``Codebook`` (decode LUT
  included).  The archive stores only the tiny encoder tables; the
  ``2**max_len``-entry decode LUT is derived on first use and shared by
  every chunk (and every archive) with the same histogram.
* **plans** -- (chunk digest, method, t_high) -> ``DecoderPlan``.  A chunk
  digest names the *decode problem* (payload bytes + framing + codebook),
  so a cached plan is valid for any archive containing that chunk --
  serving restarts and KV page-ins skip the phase 1-3 sync/count/prefix-sum
  rebuild entirely.  Plans are backend-portable (asserted by the pipeline
  tests), so the key deliberately omits the backend.

The cache is bounded (LRU on plans) because KV paging can stream an
unbounded number of distinct blocks through one process.
"""

from __future__ import annotations

import collections
import threading


class PlanCache:
    def __init__(self, max_plans: int = 4096):
        self.max_plans = max_plans
        self._books: dict = {}
        self._plans: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"plan_hits": 0, "plan_misses": 0,
                      "lut_hits": 0, "lut_misses": 0}

    # -- codebooks / LUTs ---------------------------------------------------

    def get_codebook(self, digest: str, build_fn):
        """Return the cached ``Codebook`` for ``digest``, building via
        ``build_fn()`` on first use."""
        with self._lock:
            book = self._books.get(digest)
            if book is not None:
                self.stats["lut_hits"] += 1
                return book
            self.stats["lut_misses"] += 1
        book = build_fn()
        with self._lock:
            return self._books.setdefault(digest, book)

    # -- plans ----------------------------------------------------------------

    def get_plan(self, key):
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                self.stats["plan_hits"] += 1
            else:
                self.stats["plan_misses"] += 1
            return plan

    def put_plan(self, key, plan):
        with self._lock:
            self._plans[key] = plan
            self._plans.move_to_end(key)
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)

    def clear(self):
        with self._lock:
            self._books.clear()
            self._plans.clear()

    def reset_stats(self):
        with self._lock:
            for k in self.stats:
                self.stats[k] = 0

    def __len__(self):
        return len(self._plans)


#: Process-wide default used by ``Archive`` / ``KVPager`` unless overridden.
DEFAULT_PLAN_CACHE = PlanCache()
