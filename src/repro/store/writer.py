"""Streaming archive writer with codebook dedup and atomic publish.

``ArchiveWriter`` appends chunk payloads to a temp file as tensors are
added (so a many-GiB checkpoint never has to be resident twice), then
writes the JSON index + header and atomically renames into place -- a
reader can never observe a half-written archive.

Codebooks are deduplicated by content digest: N tensors that quantize to
the same histogram (e.g. the K and V halves of a KV block, or identically
initialized layers) share one on-disk table and, via the plan cache, one
device LUT.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.huffman.pipeline import T_HIGH_DEFAULT
from repro.store import format as F


def _overall_cr_class(n_symbols: int, total_bits: int,
                      t_high: int = T_HIGH_DEFAULT) -> int:
    """Whole-chunk CR class: same (decoded bytes / encoded bytes) metric the
    per-sequence tuner uses, summarized for scheduling/stats."""
    enc_bytes = max(total_bits // 8, 1)
    ratio = n_symbols * 2 / enc_bytes
    return int(np.clip(np.ceil(ratio), 1, t_high + 1))


class ArchiveWriter:
    """Write one ``.szt`` archive; use as a context manager or call close().

    ``codec`` (default: ``repro.core.default_codec()``) only matters for
    ``add_array``, which compresses through it; ``add`` accepts
    already-compressed tensors from any codec.
    """

    def __init__(self, path: str, *, codec=None):
        self.path = path
        self._codec = codec
        self._tmp = path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f = open(self._tmp, "wb")
        self._f.write(b"\0" * F.HEADER_SIZE)
        self._off = F.HEADER_SIZE
        self._codebooks: dict[str, F.CodebookRecord] = {}
        self._chunks: list[F.ChunkRecord] = []
        self._names: set[str] = set()
        self._closed = False

    # -- low-level ----------------------------------------------------------

    def _write_blob(self, arr) -> F.BlobRef:
        pad = F.align_up(self._off) - self._off
        if pad:
            self._f.write(b"\0" * pad)
            self._off += pad
        buf = np.ascontiguousarray(arr).tobytes()
        self._f.write(buf)
        ref = F.BlobRef(offset=self._off, length=len(buf))
        self._off += len(buf)
        return ref

    def _add_codebook(self, book) -> str:
        digest = F.codebook_digest(book.enc_code, book.enc_len, book.max_len)
        if digest not in self._codebooks:
            enc_code = np.asarray(book.enc_code, np.uint32)
            enc_len = np.asarray(book.enc_len, np.uint8)
            self._codebooks[digest] = F.CodebookRecord(
                digest=digest, n_symbols=int(book.n_symbols),
                max_len=int(book.max_len),
                enc_code=self._write_blob(enc_code),
                enc_len=self._write_blob(enc_len),
                crc32=F.crc32_arrays(enc_code, enc_len))
        return digest

    # -- public -------------------------------------------------------------

    def add(self, name: str, compressed, orig_dtype: "str | None" = None):
        """Append one compressed tensor (a ``core.sz.Compressed``) as a chunk.

        ``orig_dtype`` records the dtype to cast to on restore when it
        differs from the reconstruction dtype (e.g. bfloat16 params that
        decode through float32).
        """
        if self._closed:
            raise F.StoreError("writer already closed")
        if name in self._names:
            raise F.StoreError(f"duplicate chunk name {name!r}")
        self._names.add(name)
        c = compressed
        cb_digest = self._add_codebook(c.codebook)

        units = np.asarray(c.stream.units, np.uint32)
        gaps = np.asarray(c.stream.gaps, np.uint8)
        opos = np.asarray(c.outlier_pos, np.int32)
        oval = np.asarray(c.outlier_val, np.int32)
        # Integrity CRC covers the stored (padded) blobs exactly as written;
        # the *digest* hashes only content (valid outlier prefix), so the
        # plan-cache key is independent of pad width / producing backend.
        crc = F.crc32_arrays(units, gaps, opos, oval)
        content_crc = F.payload_crc(units, gaps, opos, oval)

        units_ref = self._write_blob(units)
        total_bits = int(c.stream.total_bits)
        n_symbols = int(c.stream.n_symbols)
        sps = int(c.stream.subseqs_per_seq)
        self._chunks.append(F.ChunkRecord(
            name=name,
            shape=tuple(int(s) for s in c.shape),
            dtype=str(np.dtype(c.dtype)),
            orig_dtype=str(orig_dtype or np.dtype(c.dtype)),
            codebook=cb_digest,
            units=units_ref,
            gaps=self._write_blob(gaps),
            outlier_pos=self._write_blob(opos),
            outlier_val=self._write_blob(oval),
            bit_offset=units_ref.offset * 8,
            total_bits=total_bits,
            n_symbols=n_symbols,
            subseqs_per_seq=sps,
            eb=float(c.eb),
            radius=int(c.radius),
            rel_range=float(c.rel_range),
            max_abs=float(c.max_abs),
            cr_class=_overall_cr_class(n_symbols, total_bits),
            crc32=crc,
            digest=F.chunk_digest(content_crc, total_bits, n_symbols, sps,
                                  cb_digest),
        ))

    def add_array(self, name: str, arr, orig_dtype: "str | None" = None):
        """Compress ``arr`` through the writer's codec and append it."""
        if self._codec is None:
            from repro.core.codec import default_codec
            self._codec = default_codec()
        self.add(name, self._codec.compress(arr), orig_dtype=orig_dtype)

    def checksums(self) -> dict:
        """{chunk name: payload CRC32} for everything added so far (e.g. to
        cross-record in an external manifest)."""
        return {c.name: c.crc32 for c in self._chunks}

    def close(self):
        if self._closed:
            return
        self._closed = True
        index = F.pack_index(list(self._codebooks.values()), self._chunks)
        index_off = self._off
        self._f.write(index)
        self._f.seek(0)
        self._f.write(F.pack_header(
            n_chunks=len(self._chunks), n_codebooks=len(self._codebooks),
            index_off=index_off, index_len=len(index),
            index_crc=F.crc32_arrays(np.frombuffer(index, np.uint8))))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        os.replace(self._tmp, self.path)

    def abort(self):
        if not self._closed:
            self._closed = True
            self._f.close()
            os.unlink(self._tmp)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
        else:
            self.abort()
        return False


def write_archive(path: str, entries) -> None:
    """Write ``entries`` (iterable of (name, Compressed) or
    (name, Compressed, orig_dtype)) as one archive."""
    with ArchiveWriter(path) as w:
        for e in entries:
            w.add(*e)
