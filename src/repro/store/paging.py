"""KV-cache block paging through the compressed tensor store.

The serving cache (``models/decode``: k/v of shape (L, B, S, H, D)) is
large, cold outside the active attention window, and tolerant of bounded
error -- the paper's in-memory-compression profile.  ``KVPager`` evicts a
token range of every pageable cache tensor into one ``.szt`` archive
(one chunk per tensor, codebooks deduped across K/V) and pages it back on
demand with the batched decoder.  Repeated page-ins of the same block hit
the plan cache, so the steady-state page-in cost is pure phase-4 decode.

The paged region is zeroed after eviction: attention over masked-out
positions never reads it, and the zeros compress to nothing if the block
is re-offloaded.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core.cache import PlanCache
from repro.core.codec import Codec, default_codec
from repro.core.huffman import pipeline as hp
from repro.store import format as F
from repro.store.reader import Archive
from repro.store.writer import ArchiveWriter


class PageLostError(F.StoreError):
    """An offloaded KV block could not be read back (missing, truncated,
    corrupt, or mangled archive).

    The pager *evicts* the block and counts ``stats["pages_lost"]`` before
    raising, so the serving loop degrades (the paged span stays zeroed --
    attention re-reads masked positions as zeros) instead of crashing on a
    raw ``FileNotFoundError`` / decode error.  ``block_id`` names the block.
    """

    def __init__(self, msg: str, block_id: "int | None" = None):
        super().__init__(msg)
        self.block_id = block_id


def _pageable(name: str, arr, seq_axis: int, hi: int) -> bool:
    dt = np.dtype(str(arr.dtype)) if str(arr.dtype) != "bfloat16" else None
    is_float = (dt is None or np.issubdtype(dt, np.floating))
    return (is_float and getattr(arr, "ndim", 0) > seq_axis
            and arr.shape[seq_axis] >= hi)


class KVPager:
    """Evict / restore token ranges of a decode cache via store archives.

    One ``Codec`` drives both directions: its eb/mode compresses evicted
    blocks, its method/backend/t_high decode them back, and its plan cache
    makes repeat page-ins phase-4 only.
    """

    def __init__(self, directory: str, *, codec: "Codec | None" = None,
                 seq_axis: int = 2,
                 plan_cache: "PlanCache | None" = None):
        self.dir = directory
        self.codec = codec if codec is not None else default_codec()
        self.seq_axis = seq_axis
        self.cache = (self.codec.plan_cache if plan_cache is None
                      else plan_cache)
        os.makedirs(directory, exist_ok=True)
        self._blocks: dict = {}
        self._next_id = 0
        self.stats = {"pages_out": 0, "pages_in": 0,
                      "bytes_raw": 0, "bytes_compressed": 0,
                      "pages_lost": 0}

    def _span(self, lo: int, hi: int):
        return (slice(None),) * self.seq_axis + (slice(lo, hi),)

    def block_path(self, block_id: int) -> str:
        return os.path.join(self.dir, f"block_{block_id:06d}.szt")

    @property
    def resident_blocks(self) -> list:
        return sorted(self._blocks)

    def block_meta(self, block_id: int) -> dict:
        """{"path", "lo", "hi", "names"} of one offloaded block."""
        return dict(self._blocks[block_id])

    def _meta(self, block_id: int) -> dict:
        """Resident-block lookup for the paging paths: a non-resident id
        (never offloaded, dropped, or already evicted by a prior
        ``PageLostError``) raises the named error, so a serving loop that
        re-requests a lost block degrades instead of crashing on
        ``KeyError``."""
        meta = self._blocks.get(block_id)
        if meta is None:
            raise PageLostError(
                f"kv block {block_id} is not resident (unknown, dropped, "
                f"or already evicted after a page loss)", block_id=block_id)
        return meta

    # -- eviction -----------------------------------------------------------

    def offload(self, cache: dict, lo: int, hi: int, keys=None):
        """Compress tokens [lo, hi) of each pageable tensor to one archive.

        Returns ``(cache, block_id)`` where ``cache`` has the paged region
        zeroed for every tensor that was written.  ``keys`` defaults to all
        float tensors with a sequence axis covering the range.
        """
        if hi <= lo:
            raise ValueError(f"empty page range [{lo}, {hi})")
        candidates = cache if keys is None else keys
        keys = [k for k in candidates
                if _pageable(k, cache[k], self.seq_axis, hi)]
        if not keys:
            raise ValueError("no pageable cache tensors for range "
                             f"[{lo}, {hi})")
        block_id = self._next_id
        self._next_id += 1
        span = self._span(lo, hi)
        path = self.block_path(block_id)
        raw_bytes = 0
        with ArchiveWriter(path) as w:
            for k in keys:
                arr = cache[k]
                block = np.asarray(arr[span], np.float32)
                raw_bytes += block.size * np.dtype(
                    str(arr.dtype) if str(arr.dtype) != "bfloat16"
                    else np.float32).itemsize
                w.add(k, self.codec.compress(block),
                      orig_dtype=str(arr.dtype))
                cache[k] = arr.at[span].set(0)
        self._blocks[block_id] = {"path": path, "lo": lo, "hi": hi,
                                  "names": keys}
        self.stats["pages_out"] += 1
        self.stats["bytes_raw"] += raw_bytes
        self.stats["bytes_compressed"] += os.path.getsize(path)
        return cache, block_id

    # -- page-in ------------------------------------------------------------

    def fetch(self, block_id: int) -> dict:
        """Decode a block's tensors (device arrays), without touching any
        cache.  Plan-cache hits make repeat fetches phase-4 only.

        Any store-level failure -- missing/truncated block file, checksum
        mismatch, decode-guard trip, persistent IO error -- evicts the
        block, increments ``stats["pages_lost"]``, and raises the named
        ``PageLostError`` (with the original error chained) so callers
        catch one exception family.
        """
        meta = self._meta(block_id)
        try:
            # Chunks read with policy "raise": a partially-recovered KV
            # block is worse than a named loss -- the span is already
            # zeroed, which IS the safe degraded state.
            with Archive(meta["path"], codec=self.codec,
                         plan_cache=self.cache) as ar:
                out = ar.read_all(meta["names"], policy="raise")
            missing = [k for k in meta["names"] if k not in out]
            if missing:
                raise F.StoreCorruptError(
                    f"{meta['path']}: block is missing tensors {missing}")
        except (F.StoreError, hp.DecodeGuardError, OSError) as e:
            self._blocks.pop(block_id, None)
            self.stats["pages_lost"] += 1
            raise PageLostError(
                f"kv block {block_id} ({meta['path']}) lost: "
                f"{type(e).__name__}: {e}", block_id=block_id) from e
        self.stats["pages_in"] += 1
        return out

    def page_in(self, cache: dict, block_id: int) -> dict:
        """Restore a block into ``cache`` at its original token range.

        On a lost block (see ``fetch``) the named ``PageLostError``
        propagates; the cache is untouched and the paged span stays zeroed,
        so a caller that catches the error keeps serving degraded.
        """
        meta = self._meta(block_id)
        span = self._span(meta["lo"], meta["hi"])
        for k, block in self.fetch(block_id).items():
            cache[k] = cache[k].at[span].set(
                jnp.asarray(block, cache[k].dtype))
        return cache

    def adopt_block(self, block_id: int, meta: dict):
        """(Re-)register an offloaded block from its metadata.

        Recovery / restart path: a serving process that inherits block
        archives on disk (or re-tries a block evicted by ``PageLostError``
        after the storage heals) re-registers it here.  ``meta`` needs
        ``path`` / ``lo`` / ``hi`` / ``names`` as returned by
        ``block_meta``.
        """
        missing = {"path", "lo", "hi", "names"} - set(meta)
        if missing:
            raise ValueError(f"block meta missing keys {sorted(missing)}")
        self._blocks[block_id] = dict(meta)
        self._next_id = max(self._next_id, block_id + 1)

    def drop(self, block_id: int):
        """Forget a block and delete its archive."""
        meta = self._blocks.pop(block_id)
        if os.path.exists(meta["path"]):
            os.unlink(meta["path"])

    @property
    def ratio(self) -> float:
        return self.stats["bytes_raw"] / max(self.stats["bytes_compressed"],
                                             1)
