"""KV-cache block paging through the compressed tensor store.

The serving cache (``models/decode``: k/v of shape (L, B, S, H, D)) is
large, cold outside the active attention window, and tolerant of bounded
error -- the paper's in-memory-compression profile.  ``KVPager`` evicts a
token range of every pageable cache tensor into one ``.szt`` archive
(one chunk per tensor, codebooks deduped across K/V) and pages it back on
demand with the batched decoder.  Repeated page-ins of the same block hit
the plan cache, so the steady-state page-in cost is pure phase-4 decode.

The paged region is zeroed after eviction: attention over masked-out
positions never reads it, and the zeros compress to nothing if the block
is re-offloaded.

Concurrency: one pager may be shared by many serving sessions (the
``repro.serving`` scheduler does exactly that), so all block-table and
counter mutations happen under an internal lock.  The decode work itself
is *not* serialized here -- ``stage`` (host read + CRC + plan) and
``decode_staged`` (one class-merged ``decompress_batch`` across blocks)
split the page-in into the two pipeline stages the scheduler overlaps.
"""

from __future__ import annotations

import dataclasses
import os
import threading

import jax.numpy as jnp
import numpy as np

from repro.core.cache import PlanCache
from repro.core.codec import Codec, default_codec
from repro.core.huffman import pipeline as hp
from repro.store import format as F
from repro.store.reader import Archive
from repro.store.writer import ArchiveWriter


class PageLostError(F.StoreError):
    """An offloaded KV block could not be read back (missing, truncated,
    corrupt, or mangled archive).

    The pager *evicts* the block and counts ``stats["pages_lost"]`` before
    raising, so the serving loop degrades (the paged span stays zeroed --
    attention re-reads masked positions as zeros) instead of crashing on a
    raw ``FileNotFoundError`` / decode error.  ``block_id`` names the block.
    """

    def __init__(self, msg: str, block_id: "int | None" = None):
        super().__init__(msg)
        self.block_id = block_id


def _pageable(name: str, arr, seq_axis: int, hi: int) -> bool:
    dt = np.dtype(str(arr.dtype)) if str(arr.dtype) != "bfloat16" else None
    is_float = (dt is None or np.issubdtype(dt, np.floating))
    return (is_float and getattr(arr, "ndim", 0) > seq_axis
            and arr.shape[seq_axis] >= hi)


@dataclasses.dataclass
class StagedBlock:
    """One block's host-side half of a page-in: chunks read + CRC-checked,
    phase 1-3 plans resolved (cache hits for repeats), no decode yet.

    ``key`` is the block's *content* identity -- the sorted (tensor name,
    chunk digest) pairs -- so two blocks holding identical bytes (e.g. the
    same shared prompt prefix offloaded twice) compare equal and can share
    one decode (``repro.serving.prefix_cache`` keys on it).
    """

    block_id: int
    key: tuple
    names: list
    cs: list
    plans: list
    meta: dict

    @property
    def decoded_bytes(self) -> int:
        """Size of the decoded (float32) tensors this block expands to."""
        return sum(int(np.prod(c.shape)) * 4 for c in self.cs)


class KVPager:
    """Evict / restore token ranges of a decode cache via store archives.

    One ``Codec`` drives both directions: its eb/mode compresses evicted
    blocks, its method/backend/t_high decode them back, and its plan cache
    makes repeat page-ins phase-4 only.  Safe to share across threads: the
    block table (``_blocks``), id counter, and ``stats`` are guarded by one
    reentrant lock.
    """

    def __init__(self, directory: str, *, codec: "Codec | None" = None,
                 seq_axis: int = 2,
                 plan_cache: "PlanCache | None" = None):
        self.dir = directory
        self.codec = codec if codec is not None else default_codec()
        self.seq_axis = seq_axis
        self.cache = (self.codec.plan_cache if plan_cache is None
                      else plan_cache)
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.RLock()
        self._blocks: dict = {}
        self._next_id = 0
        self.stats = {"pages_out": 0, "pages_in": 0,
                      "bytes_raw": 0, "bytes_compressed": 0,
                      "pages_lost": 0}

    def _bump(self, key: str, n: int = 1):
        with self._lock:
            self.stats[key] += n

    def _span(self, lo: int, hi: int):
        return (slice(None),) * self.seq_axis + (slice(lo, hi),)

    def block_path(self, block_id: int) -> str:
        return os.path.join(self.dir, f"block_{block_id:06d}.szt")

    @property
    def resident_blocks(self) -> list:
        with self._lock:
            return sorted(self._blocks)

    def block_meta(self, block_id: int) -> dict:
        """{"path", "lo", "hi", "names"} of one offloaded block."""
        with self._lock:
            return dict(self._blocks[block_id])

    def _meta(self, block_id: int) -> dict:
        """Resident-block lookup for the paging paths: a non-resident id
        (never offloaded, dropped, or already evicted by a prior
        ``PageLostError``) raises the named error, so a serving loop that
        re-requests a lost block degrades instead of crashing on
        ``KeyError``."""
        with self._lock:
            meta = self._blocks.get(block_id)
        if meta is None:
            raise PageLostError(
                f"kv block {block_id} is not resident (unknown, dropped, "
                f"or already evicted after a page loss)", block_id=block_id)
        return meta

    def _lose(self, block_id: int, path: str, exc) -> PageLostError:
        """Evict + count a lost block; returns the named error to raise."""
        with self._lock:
            self._blocks.pop(block_id, None)
            self.stats["pages_lost"] += 1
        return PageLostError(
            f"kv block {block_id} ({path}) lost: "
            f"{type(exc).__name__}: {exc}", block_id=block_id)

    # -- eviction -----------------------------------------------------------

    def offload(self, cache: dict, lo: int, hi: int, keys=None):
        """Compress tokens [lo, hi) of each pageable tensor to one archive.

        Returns ``(cache, block_id)`` where ``cache`` has the paged region
        zeroed for every tensor that was written.  ``keys`` defaults to all
        float tensors with a sequence axis covering the range.
        """
        if hi <= lo:
            raise ValueError(f"empty page range [{lo}, {hi})")
        candidates = cache if keys is None else keys
        keys = [k for k in candidates
                if _pageable(k, cache[k], self.seq_axis, hi)]
        if not keys:
            raise ValueError("no pageable cache tensors for range "
                             f"[{lo}, {hi})")
        with self._lock:
            block_id = self._next_id
            self._next_id += 1
        span = self._span(lo, hi)
        path = self.block_path(block_id)
        raw_bytes = 0
        with ArchiveWriter(path) as w:
            for k in keys:
                arr = cache[k]
                block = np.asarray(arr[span], np.float32)
                raw_bytes += block.size * np.dtype(
                    str(arr.dtype) if str(arr.dtype) != "bfloat16"
                    else np.float32).itemsize
                w.add(k, self.codec.compress(block),
                      orig_dtype=str(arr.dtype))
                cache[k] = arr.at[span].set(0)
        with self._lock:
            self._blocks[block_id] = {"path": path, "lo": lo, "hi": hi,
                                      "names": keys}
            self.stats["pages_out"] += 1
            self.stats["bytes_raw"] += raw_bytes
            self.stats["bytes_compressed"] += os.path.getsize(path)
        return cache, block_id

    # -- page-in ------------------------------------------------------------

    def block_key(self, block_id: int) -> tuple:
        """Content identity of a block: sorted (name, chunk digest) pairs.

        Index-only read (no chunk payload, CRC, or decode), memoized in the
        block table -- the serving scheduler's prefix cache calls this per
        request to detect blocks whose decode can be shared.  A missing /
        corrupt archive evicts the block and raises ``PageLostError``.
        """
        meta = self._meta(block_id)
        key = meta.get("key")
        if key is not None:
            return key
        try:
            with Archive(meta["path"], codec=self.codec,
                         plan_cache=self.cache) as ar:
                key = tuple(sorted(
                    (n, ar.chunk(n).digest) for n in meta["names"]))
        except (F.StoreError, OSError, KeyError) as e:
            raise self._lose(block_id, meta["path"], e) from e
        with self._lock:
            live = self._blocks.get(block_id)
            if live is not None:
                live["key"] = key
        return key

    def stage(self, block_id: int) -> StagedBlock:
        """Host half of a page-in: read + CRC-check every chunk of the
        block and resolve its phase 1-3 plans (plan-cache hits on repeats).

        No decode dispatch happens here, so this is safe to run on an I/O
        thread while the device decodes another block's batch
        (``decode_staged``).  Failures evict + count the block and raise
        ``PageLostError``.
        """
        meta = self._meta(block_id)
        try:
            with Archive(meta["path"], codec=self.codec,
                         plan_cache=self.cache) as ar:
                missing = [k for k in meta["names"] if k not in ar]
                if missing:
                    raise F.StoreCorruptError(
                        f"{meta['path']}: block is missing tensors "
                        f"{missing}")
                cs = [ar.read_chunk(n) for n in meta["names"]]
                key = tuple(sorted(
                    (n, ar.chunk(n).digest) for n in meta["names"]))
            plans = [self.codec.plan_for(c) for c in cs]
        except (F.StoreError, hp.DecodeGuardError, OSError) as e:
            raise self._lose(block_id, meta["path"], e) from e
        with self._lock:
            live = self._blocks.get(block_id)
            if live is not None:
                live["key"] = key
        return StagedBlock(block_id=block_id, key=key,
                           names=list(meta["names"]), cs=cs, plans=plans,
                           meta=meta)

    def decode_staged(self, staged, *, on_lost=None) -> dict:
        """Decode staged blocks: ONE class-merged ``decompress_batch`` over
        every tensor of every block.  Returns {block_id: {name: array}}.

        A block whose decode trips a guard (malformed stream) is salvaged
        out of the batch: it is evicted + counted, and either ``on_lost
        (block_id, exc)`` absorbs it or the named ``PageLostError`` raises.
        """
        staged = list(staged)
        if not staged:
            return {}
        all_cs = [c for s in staged for c in s.cs]
        all_plans = [p for s in staged for p in s.plans]
        out: dict = {}
        try:
            decoded = self.codec.decompress_batch(all_cs, plans=all_plans)
            i = 0
            for s in staged:
                out[s.block_id] = dict(zip(s.names,
                                           decoded[i:i + len(s.names)]))
                i += len(s.names)
        except hp.DecodeGuardError:
            # Per-block salvage: one malformed stream must not take down
            # its batch-mates.
            for s in staged:
                try:
                    decoded = self.codec.decompress_batch(s.cs,
                                                          plans=s.plans)
                    out[s.block_id] = dict(zip(s.names, decoded))
                except hp.DecodeGuardError as e:
                    err = self._lose(s.block_id, s.meta["path"], e)
                    if on_lost is None:
                        raise err from e
                    on_lost(s.block_id, err)
        self._bump("pages_in", len(out))
        return out

    def fetch(self, block_id: int) -> dict:
        """Decode a block's tensors (device arrays), without touching any
        cache.  Plan-cache hits make repeat fetches phase-4 only.

        Any store-level failure -- missing/truncated block file, checksum
        mismatch, decode-guard trip, persistent IO error -- evicts the
        block, increments ``stats["pages_lost"]``, and raises the named
        ``PageLostError`` (with the original error chained) so callers
        catch one exception family.
        """
        return self.decode_staged([self.stage(block_id)])[block_id]

    def fetch_many(self, block_ids, *, on_lost=None) -> dict:
        """Batched ``fetch``: stage every block, then decode them ALL in one
        class-merged dispatch set.  Returns {block_id: {name: array}}.

        With ``on_lost(block_id, exc)`` a lost block (missing / corrupt /
        guard-tripped archive -- evicted + counted as usual) is reported and
        skipped; without it the first ``PageLostError`` propagates.
        """
        staged = []
        for bid in block_ids:
            try:
                staged.append(self.stage(bid))
            except PageLostError as e:
                if on_lost is None:
                    raise
                on_lost(bid, e)
        return self.decode_staged(staged, on_lost=on_lost)

    def page_in(self, cache: dict, block_id: int) -> dict:
        """Restore a block into ``cache`` at its original token range.

        On a lost block (see ``fetch``) the named ``PageLostError``
        propagates; the cache is untouched and the paged span stays zeroed,
        so a caller that catches the error keeps serving degraded.
        """
        meta = self._meta(block_id)
        span = self._span(meta["lo"], meta["hi"])
        for k, block in self.fetch(block_id).items():
            cache[k] = cache[k].at[span].set(
                jnp.asarray(block, cache[k].dtype))
        return cache

    def adopt_block(self, block_id: int, meta: dict):
        """(Re-)register an offloaded block from its metadata.

        Recovery / restart path: a serving process that inherits block
        archives on disk (or re-tries a block evicted by ``PageLostError``
        after the storage heals) re-registers it here.  ``meta`` needs
        ``path`` / ``lo`` / ``hi`` / ``names`` as returned by
        ``block_meta``.
        """
        missing = {"path", "lo", "hi", "names"} - set(meta)
        if missing:
            raise ValueError(f"block meta missing keys {sorted(missing)}")
        with self._lock:
            self._blocks[block_id] = dict(meta)
            self._next_id = max(self._next_id, block_id + 1)

    def drop(self, block_id: int):
        """Forget a block and delete its archive.

        Dropping a non-resident id raises the named ``PageLostError``
        (matching the paging paths), not a bare ``KeyError``.
        """
        meta = self._meta(block_id)
        with self._lock:
            self._blocks.pop(block_id, None)
        if os.path.exists(meta["path"]):
            os.unlink(meta["path"])

    @property
    def ratio(self) -> float:
        """Achieved compression ratio; ``0.0`` until something has been
        offloaded (no more ``bytes_raw / 1`` nonsense on an idle pager)."""
        with self._lock:
            if self.stats["bytes_compressed"] == 0:
                return 0.0
            return self.stats["bytes_raw"] / self.stats["bytes_compressed"]
