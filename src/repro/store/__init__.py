"""Compressed tensor store: chunked ``.szt`` archives + paging over a Codec.

Public surface:
  * ``ArchiveWriter`` / ``write_archive``  -- build an archive (codebooks
    deduped by digest, per-chunk CRC32, atomic publish); ``add_array``
    compresses through the writer's codec.
  * ``Archive`` / ``open_archive``         -- mmap reader; ``read_all`` /
    ``iter_decode`` overlap disk reads with batched device decode.  Decode
    policy and the plan cache come from the ``codec=`` the archive was
    opened with (default: ``repro.core.default_codec()``).
  * ``KVPager``                            -- evict / restore KV-cache token
    ranges through archives, one codec for both directions.
  * ``StoreError`` hierarchy               -- ``StoreVersionError`` for
    incompatible archives, ``StoreCorruptError`` for truncation/checksum,
    ``StoreIOError`` for OS reads that failed after retries, and
    ``PageLostError`` for an unreadable KV block (evicted + counted in
    ``KVPager.stats["pages_lost"]``).  Recovery policies ("raise" / "skip"
    / "zero_fill" + transient-IO retry) thread through from the codec; see
    docs/robustness.md.

``PlanCache`` / ``DEFAULT_PLAN_CACHE`` now live in ``repro.core.cache``
(the Codec owns plan reuse); they are re-exported here for compatibility.
"""

from repro.core.cache import DEFAULT_PLAN_CACHE, PlanCache  # noqa: F401
from repro.store.format import (  # noqa: F401
    FORMAT_VERSION,
    StoreCorruptError,
    StoreError,
    StoreIOError,
    StoreVersionError,
)
from repro.store.paging import KVPager, PageLostError  # noqa: F401
from repro.store.reader import Archive, open_archive  # noqa: F401
from repro.store.writer import ArchiveWriter, write_archive  # noqa: F401
