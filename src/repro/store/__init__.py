"""Compressed tensor store: chunked ``.szt`` archives + plan cache + paging.

Public surface:
  * ``ArchiveWriter`` / ``write_archive``  -- build an archive (codebooks
    deduped by digest, per-chunk CRC32, atomic publish).
  * ``Archive`` / ``open_archive``         -- mmap reader; ``read_all`` /
    ``iter_decode`` overlap disk reads with batched device decode.
  * ``PlanCache`` / ``DEFAULT_PLAN_CACHE`` -- digest-keyed plan + LUT reuse
    across opens (restore, serving restarts, KV page-ins).
  * ``KVPager``                            -- evict / restore KV-cache token
    ranges through archives.
  * ``StoreError`` hierarchy               -- ``StoreVersionError`` for
    incompatible archives, ``StoreCorruptError`` for truncation/checksum.
"""

from repro.store.cache import DEFAULT_PLAN_CACHE, PlanCache  # noqa: F401
from repro.store.format import (  # noqa: F401
    FORMAT_VERSION,
    StoreCorruptError,
    StoreError,
    StoreVersionError,
)
from repro.store.paging import KVPager  # noqa: F401
from repro.store.reader import Archive, open_archive  # noqa: F401
from repro.store.writer import ArchiveWriter, write_archive  # noqa: F401
