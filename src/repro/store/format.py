"""On-disk layout of the compressed tensor store (``.szt`` archives).

One archive holds MANY compressed tensors (chunks) plus a *deduplicated*
codebook table; see ``docs/format.md`` for the normative byte-level spec.
Layout (all integers little-endian):

    [ header | payload blobs ... | index (JSON) ]

* **Header** -- fixed ``HEADER_SIZE`` bytes at offset 0: magic, format
  version, chunk/codebook counts, and the (offset, length, crc32) of the
  index section.  The header is the only thing a reader must parse before
  it can seek anywhere, which keeps the open path one small read + one
  index read even for multi-GiB archives.
* **Payload blobs** -- raw C-order array bytes, each aligned to
  ``BLOB_ALIGN`` so an mmap'd archive yields aligned, zero-copy
  ``np.frombuffer`` views.  Blobs are the encoded unit arrays, gap arrays,
  outlier side lists, and the codebook tables.
* **Index** -- one JSON object (codebook records + chunk records) at the
  end of the file, so the writer can stream payload first and the reader
  can locate everything from the header.

Chunk records carry the *bit* offset and length of the tensor's payload
inside the units blob space, the gap-array blob, the CR class summary, and
a CRC32 over the chunk's payload bytes.  Codebook records are keyed by a
content digest; two tensors with identical histograms share one table on
disk and one decode LUT in memory.
"""

from __future__ import annotations

import dataclasses
import json
import struct

import numpy as np

from repro.core.cache import (  # noqa: F401  (re-exports: digests moved to
    chunk_digest,               # core so the Codec's plan-cache keys and the
    codebook_digest,            # archive's are one namespace)
    crc32_arrays,
    payload_crc,
)

MAGIC = b"SZTSTORE"
FORMAT_VERSION = 1
HEADER_SIZE = 64
BLOB_ALIGN = 64

# --- sharded layout (repro.distributed) -----------------------------------
# A mesh-sharded archive is a directory: one JSON manifest mapping entries
# to per-host tile chunks, plus N ordinary ``.szt`` shard files (each a
# fully self-describing archive of this format).  The manifest version is
# independent of FORMAT_VERSION: shard payload bytes never change meaning
# when the manifest schema evolves.
SHARD_MANIFEST_NAME = "shard_manifest.json"
SHARD_MANIFEST_VERSION = 1


def shard_filename(shard: int) -> str:
    """Canonical shard file name inside a sharded-archive directory."""
    return f"shard_{shard:05d}.szt"

# struct: magic, version, flags, n_chunks, n_codebooks, index_off, index_len,
# index_crc, then zero padding up to HEADER_SIZE.
_HEADER_FMT = "<8sIIIIQQI"
_HEADER_USED = struct.calcsize(_HEADER_FMT)


class StoreError(RuntimeError):
    """Base class for archive format errors."""


class StoreVersionError(StoreError):
    """Archive was written by an incompatible format version."""


class StoreCorruptError(StoreError):
    """Archive is truncated or fails a checksum."""


class StoreIOError(StoreError):
    """An OS-level read failed and retries (if configured) were exhausted.

    Wraps the underlying ``OSError`` so store consumers catch one exception
    family whether bytes were corrupt or the filesystem misbehaved."""


@dataclasses.dataclass(frozen=True)
class BlobRef:
    """Byte extent of one payload blob inside the archive file."""

    offset: int
    length: int

    def to_json(self):
        return [self.offset, self.length]

    @classmethod
    def from_json(cls, v) -> "BlobRef":
        return cls(offset=int(v[0]), length=int(v[1]))


@dataclasses.dataclass
class CodebookRecord:
    """One deduplicated codebook table (referenced by chunks via digest)."""

    digest: str              # content digest of (enc_code, enc_len, max_len)
    n_symbols: int
    max_len: int
    enc_code: BlobRef        # uint32[n_symbols]
    enc_len: BlobRef         # uint8[n_symbols]
    crc32: int               # CRC32 over (enc_code, enc_len) payload bytes

    def to_json(self):
        return {"digest": self.digest, "n_symbols": self.n_symbols,
                "max_len": self.max_len, "enc_code": self.enc_code.to_json(),
                "enc_len": self.enc_len.to_json(), "crc32": self.crc32}

    @classmethod
    def from_json(cls, d) -> "CodebookRecord":
        return cls(digest=d["digest"], n_symbols=int(d["n_symbols"]),
                   max_len=int(d["max_len"]),
                   enc_code=BlobRef.from_json(d["enc_code"]),
                   enc_len=BlobRef.from_json(d["enc_len"]),
                   crc32=int(d["crc32"]))


@dataclasses.dataclass
class ChunkRecord:
    """One compressed tensor: payload extents + decode metadata + checksum."""

    name: str
    shape: tuple
    dtype: str               # reconstruction dtype of the decoded tensor
    orig_dtype: str          # dtype of the original array (may be bfloat16)
    codebook: str            # digest key into the codebook table
    units: BlobRef           # uint32 payload units
    gaps: BlobRef            # uint8[n_subseq] gap array
    outlier_pos: BlobRef     # int32[m_pad]
    outlier_val: BlobRef     # int32[m_pad]
    bit_offset: int          # bit position of this chunk in the units space
    total_bits: int
    n_symbols: int           # quantization codes encoded in the stream
    subseqs_per_seq: int
    eb: float
    radius: int
    rel_range: float
    max_abs: float
    cr_class: int            # ceil(overall CR) clipped to [1, t_high+1]
    crc32: int               # CRC32 over the chunk's payload bytes
    digest: str              # stable content digest (plan-cache key)

    def to_json(self):
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        for f in ("units", "gaps", "outlier_pos", "outlier_val"):
            d[f] = getattr(self, f).to_json()
        return d

    @classmethod
    def from_json(cls, d) -> "ChunkRecord":
        kw = dict(d)
        kw["shape"] = tuple(int(s) for s in d["shape"])
        for f in ("units", "gaps", "outlier_pos", "outlier_val"):
            kw[f] = BlobRef.from_json(d[f])
        return cls(**kw)


def pack_header(n_chunks: int, n_codebooks: int, index_off: int,
                index_len: int, index_crc: int) -> bytes:
    head = struct.pack(_HEADER_FMT, MAGIC, FORMAT_VERSION, 0,
                       n_chunks, n_codebooks, index_off, index_len, index_crc)
    return head + b"\0" * (HEADER_SIZE - _HEADER_USED)


def unpack_header(buf: bytes) -> dict:
    if len(buf) < HEADER_SIZE:
        raise StoreCorruptError(
            f"archive truncated: {len(buf)} bytes is smaller than the "
            f"{HEADER_SIZE}-byte header")
    magic, version, _flags, n_chunks, n_codebooks, index_off, index_len, \
        index_crc = struct.unpack(_HEADER_FMT, buf[:_HEADER_USED])
    if magic != MAGIC:
        raise StoreError(f"not a tensor-store archive (magic {magic!r})")
    if version != FORMAT_VERSION:
        raise StoreVersionError(
            f"archive format version {version} unsupported "
            f"(reader supports {FORMAT_VERSION})")
    return {"n_chunks": n_chunks, "n_codebooks": n_codebooks,
            "index_off": index_off, "index_len": index_len,
            "index_crc": index_crc}


def pack_index(codebooks: list, chunks: list) -> bytes:
    doc = {"codebooks": [c.to_json() for c in codebooks],
           "chunks": [c.to_json() for c in chunks]}
    return json.dumps(doc, separators=(",", ":")).encode("utf-8")


def unpack_index(buf: bytes) -> tuple:
    try:
        doc = json.loads(buf.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise StoreCorruptError(f"archive index is unreadable: {e}") from e
    try:
        records = ([CodebookRecord.from_json(c) for c in doc["codebooks"]],
                   [ChunkRecord.from_json(c) for c in doc["chunks"]])
    except (KeyError, TypeError, ValueError, IndexError) as e:
        # CRC-valid JSON with mangled structure (e.g. an in-memory mutation
        # before the CRC was stamped) must still fail with a named error.
        raise StoreCorruptError(
            f"archive index is structurally invalid: "
            f"{type(e).__name__}: {e}") from e
    for rec in records[1]:
        validate_record(rec)
    return records


def _dtype_ok(name) -> bool:
    """True when ``name`` parses as a numpy or ml_dtypes (bfloat16 etc.)
    dtype -- the two families ``jnp.asarray(..., dtype=name)`` accepts."""
    try:
        np.dtype(name)
        return True
    except TypeError:
        pass
    try:
        import ml_dtypes
        np.dtype(getattr(ml_dtypes, str(name)))
        return True
    except (ImportError, AttributeError, TypeError):
        return False


def validate_record(rec: ChunkRecord) -> None:
    """Sanity-check a parsed chunk record before any payload is touched.

    The index CRC proves the *bytes* of the index survived; this proves the
    *values* are self-consistent, so a record mangled before it was CRC'd
    (or mutated in memory) cannot drive giant allocations or unnamed
    downstream errors.  Raises ``StoreCorruptError``.
    """
    problems = []
    for f in ("units", "gaps", "outlier_pos", "outlier_val"):
        ref = getattr(rec, f)
        if ref.offset < 0 or ref.length < 0:
            problems.append(f"negative {f} extent {ref.offset}+{ref.length}")
    n = 1
    for s in rec.shape:
        if s < 0:
            problems.append(f"negative dimension in shape {rec.shape}")
            break
        n *= s
    else:
        if n != rec.n_symbols:
            problems.append(f"n_symbols={rec.n_symbols} != prod(shape "
                            f"{rec.shape})={n}")
    if rec.total_bits < 0:
        problems.append(f"negative total_bits {rec.total_bits}")
    elif rec.total_bits > 8 * rec.units.length:
        problems.append(f"total_bits={rec.total_bits} exceeds the units "
                        f"blob ({rec.units.length} bytes)")
    if rec.subseqs_per_seq < 1:
        problems.append(f"subseqs_per_seq={rec.subseqs_per_seq} < 1")
    for f in ("dtype", "orig_dtype"):
        if not _dtype_ok(getattr(rec, f)):
            problems.append(f"unparseable {f} {getattr(rec, f)!r}")
    if problems:
        raise StoreCorruptError(
            f"chunk record {rec.name!r} is invalid: " + "; ".join(problems))


def align_up(off: int, align: int = BLOB_ALIGN) -> int:
    return (off + align - 1) // align * align
