"""Pallas kernels for the Lorenzo transform (cuSZ dual-quant, DESIGN.md §3).

``quantize1d`` is fully parallel (dual-quantization removed the loop-carried
dependence); ``reconstruct1d`` is the inverse prefix sum, implemented with a
block-local cumsum plus a carry kept in VMEM scratch across the sequential
grid -- the standard single-pass chained-scan structure.

2-D/3-D Lorenzo is composed at the ops level from per-axis applications
(the per-axis pass is the same 1-D kernel applied to rows); see
``repro.kernels.ops.lorenzo_*``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, xprev_ref, teb_ref, o_code_ref, o_out_ref,
                  o_resid_ref, *, radius):
    # two_eb arrives as a runtime input: XLA strength-reduces division by a
    # *constant* to a reciprocal multiply, which flips lattice ties vs the
    # jnp oracle (whose eb is a traced argument -> true division).
    x = x_ref[...]
    xp = xprev_ref[...]
    two_eb = teb_ref[0]
    q = jnp.round(x / two_eb).astype(jnp.int32)
    qp = jnp.round(xp / two_eb).astype(jnp.int32)
    d = q - qp
    code = d + radius
    outlier = (code < 0) | (code >= 2 * radius)
    o_code_ref[...] = jnp.where(outlier, 0, code).astype(jnp.uint16)
    o_out_ref[...] = outlier.astype(jnp.int8)
    o_resid_ref[...] = d


@functools.partial(
    jax.jit, static_argnames=("eb", "radius", "block", "interpret"))
def quantize1d(x, eb, radius: int = 512, block: int = 4096,
               interpret: bool = True):
    """1-D dual-quant Lorenzo: returns (codes u16, outlier i8, residual i32).

    The predecessor element crosses block boundaries, so the shifted copy is
    passed as a second input (built by ops with a cheap roll).
    """
    n = x.shape[0]
    assert n % block == 0
    xprev = jnp.roll(x, 1).at[0].set(0.0)
    grid = (n // block,)
    two_eb = jnp.full((1,), 2.0 * eb, jnp.float32)
    return pl.pallas_call(
        functools.partial(_quant_kernel, radius=radius),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint16),
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(x, xprev, two_eb)


def _recon_kernel(d_ref, o_ref, carry, *, two_eb):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry[0] = jnp.int32(0)

    d = d_ref[...].astype(jnp.int32)
    q = jnp.cumsum(d) + carry[0]
    carry[0] = q[-1]
    o_ref[...] = q.astype(jnp.float32) * two_eb


@functools.partial(jax.jit, static_argnames=("eb", "block", "interpret"))
def reconstruct1d(d, eb, block: int = 4096, interpret: bool = True):
    """Inverse 1-D Lorenzo: chained block cumsum, x = 2*eb * prefix(d)."""
    n = d.shape[0]
    assert n % block == 0
    two_eb = float(2.0 * eb)
    return pl.pallas_call(
        functools.partial(_recon_kernel, two_eb=two_eb),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(d)
