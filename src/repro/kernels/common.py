"""Shared in-kernel decode helpers.

These operate on *values* (arrays already loaded from refs), never on refs,
so the same code runs inside Pallas kernel bodies and in the jnp oracles.

Coordinate system: every decoder lane owns a private row of ``ROW_UNITS``
uint32 units covering its 128-bit subsequence plus overhang
(128 + max_len + 31 < 192 bits -> 6 units).  Bit positions are local to the
row: the subsequence body is [0, 128), decode may run to < 192.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ROW_UNITS = 6           # 192 bits >= 128 (body) + 12 (max codeword) + 31 (align)
MAX_SYMS = 128          # worst case: 128 one-bit codewords per subsequence


def peek_rows(rows: jnp.ndarray, pos: jnp.ndarray, max_len: int) -> jnp.ndarray:
    """Per-lane peek: rows (L, ROW_UNITS) uint32, pos (L,) local bits.

    Returns (L,) int32 LUT indices (the next ``max_len`` bits of each lane).
    """
    lanes = rows.shape[0]
    r = rows.shape[1]
    pos = pos.astype(jnp.int32)
    u = jnp.clip(pos >> 5, 0, r - 1)
    sh = (pos & 31).astype(jnp.uint32)
    flat = rows.reshape(-1)
    base = jnp.arange(lanes, dtype=jnp.int32) * r
    w0 = flat[base + u]
    u1 = jnp.clip(u + 1, 0, r - 1)
    w1 = jnp.where(u + 1 < r, flat[base + u1], jnp.uint32(0))
    hi = w0 << sh
    lo = jnp.where(sh == 0, jnp.uint32(0), w1 >> (jnp.uint32(32) - sh))
    window = hi | lo
    return (window >> jnp.uint32(32 - max_len)).astype(jnp.int32)


def decode_window(rows, start, end, dec_sym, dec_len, max_len: int,
                  collect: bool, lut_base=None):
    """Masked decode of per-lane windows [start, end) (local bit coords).

    ``lut_base`` (optional int32[L]) offsets each lane's LUT index into a
    merged multi-codebook decode table (the batched multi-tensor path).

    The loop is a ``while_loop`` whose predicate is "any lane still active"
    -- the TPU analogue of the paper's `__all_sync` early exit.  Returns
    (landing_pos, counts[, padded_syms (L, MAX_SYMS)]).
    """
    lanes = rows.shape[0]
    start = start.astype(jnp.int32)
    end = end.astype(jnp.int32)
    syms0 = jnp.zeros((lanes, MAX_SYMS), jnp.uint16) if collect else None

    def cond(state):
        pos, count, syms = state
        return jnp.any(pos < end)

    def body(state):
        pos, count, syms = state
        active = pos < end
        win = peek_rows(rows, pos, max_len)
        if lut_base is not None:
            win = win + lut_base
        # Guard: clamp the LUT gather -- inside a compiled Pallas kernel an
        # out-of-bounds gather is undefined behaviour, and a corrupt
        # merged-LUT offset must not escape the table.
        win = jnp.clip(win, 0, dec_sym.shape[0] - 1)
        sym = dec_sym[win]
        length = dec_len[win].astype(jnp.int32)
        if collect:
            idx = jnp.clip(count, 0, MAX_SYMS - 1)
            lane = jnp.arange(lanes)
            upd = jnp.where(active, sym, syms[lane, idx])
            syms = syms.at[lane, idx].set(upd)
        count = jnp.where(active, count + 1, count)
        pos = jnp.where(active, pos + jnp.maximum(length, 1), pos)
        return pos, count, syms

    # negative local starts can reach padded tail lanes via the selfsync
    # landing chain (landing - 128 < 0 when the window was total_bits-
    # clamped); clamp so such lanes stay inactive.
    pos0 = jnp.clip(jnp.minimum(start, end), 0, None)
    pos, count, syms = jax.lax.while_loop(
        cond, body, (pos0, jnp.zeros(lanes, jnp.int32), syms0))
    if collect:
        return pos, count, syms
    return pos, count


def decode_window_fixed(rows, start, end, dec_sym, dec_len, max_len: int):
    """Baseline variant without early exit: always runs MAX_SYMS rounds
    (the worst-case bound the paper's `__all_sync` optimization removes).
    Returns (landing_pos, counts)."""
    lanes = rows.shape[0]
    start = start.astype(jnp.int32)
    end = end.astype(jnp.int32)

    def body(_k, state):
        pos, count = state
        active = pos < end
        win = jnp.clip(peek_rows(rows, pos, max_len), 0,
                       dec_len.shape[0] - 1)
        length = dec_len[win].astype(jnp.int32)
        count = jnp.where(active, count + 1, count)
        pos = jnp.where(active, pos + jnp.maximum(length, 1), pos)
        return pos, count

    pos0 = jnp.clip(jnp.minimum(start, end), 0, None)
    return jax.lax.fori_loop(
        0, MAX_SYMS, body, (pos0, jnp.zeros(lanes, jnp.int32)))


def stage_tile(rows, start, end, off, lut_base, dec_sym, dec_len,
               max_len: int, tile_syms: int) -> jnp.ndarray:
    """Decode the lanes overlapping one output tile into a dense staging tile.

    Shared body of ``decode_tiles_kernel_body`` and its fused variant: each
    lane decodes its window and scatters its symbols to tile-local positions
    (``off`` is the lane's output offset minus the tile base; out-of-tile
    positions are dropped).  Returns the uint16[tile_syms] staging tile.
    """
    _, counts, padded = decode_window(rows, start, end, dec_sym, dec_len,
                                      max_len, collect=True,
                                      lut_base=lut_base)
    k = jnp.arange(MAX_SYMS, dtype=jnp.int32)[None, :]
    local = off[:, None] + k
    valid = (k < counts[:, None]) & (local >= 0) & (local < tile_syms)
    tile = jnp.zeros((tile_syms,), jnp.uint16)
    return tile.at[jnp.where(valid, local, tile_syms)].set(
        jnp.where(valid, padded, 0), mode="drop")


def gather_subseq_rows(units: jnp.ndarray, subseq_ids: jnp.ndarray):
    """Build per-subsequence unit rows: row[s] = units[4*s : 4*s + ROW_UNITS].

    ``units`` is the full stream (uint32[U]); out-of-range reads are zero
    (the encoder's tail padding semantics).
    """
    idx = subseq_ids[..., None] * 4 + jnp.arange(ROW_UNITS, dtype=jnp.int32)
    in_range = idx < units.shape[0]
    return jnp.where(in_range,
                     units[jnp.clip(idx, 0, units.shape[0] - 1)],
                     jnp.uint32(0))
