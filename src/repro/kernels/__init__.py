# Pallas TPU kernels for the paper's compute hot spots:
#   huffman_decode.py    -- phase-1 count + tile-staged decode-write (Alg. 1)
#   huffman_selfsync.py  -- sync-point discovery with early exit (__all_sync)
#   fused_decode.py      -- decode-write + dequant + inverse-Lorenzo epilogue
#   histogram.py         -- Gomez-Luna-style histogram (codebook + tuner)
#   lorenzo.py           -- dual-quant Lorenzo fwd/inv (cuSZ (de)compression)
# ops.py = jit'd wrappers; ref.py = pure-jnp oracles (single source of truth).
from repro.kernels import common, ops, ref  # noqa: F401
