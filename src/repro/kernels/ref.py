"""Pure-jnp oracles for every Pallas kernel (signature-matched to ops.py).

These delegate to the reference implementations in ``repro.core`` so each
kernel has exactly one source of truth; tests sweep shapes / dtypes /
codebook skews and assert bit-exact agreement with ``repro.kernels.ops``.

The fused decode ops (``ops.decode_write_tiles_fused`` /
``ops.decode_padded_fused``) have no mirror here: their oracle is the
decode + ``core.sz.lorenzo.dequantize`` composition that the "ref" decode
backend registers (``core.huffman.pipeline._make_ref_backend``), which is
N-D and dtype-general by construction (``dequantize`` cumsums along every
axis and casts once at the end).  It is asserted bit-exact against the
kernels -- the 1-D chained-carry epilogue and the 2-D/3-D row/plane-carry
epilogue of ``kernels/fused_decode.py``, over float32 / bfloat16 / float16
-- by the fused parity matrices in ``tests/test_pipeline.py``,
``tests/test_codec.py`` and ``tests/test_fused_nd.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he
from repro.core.huffman.bits import SUBSEQ_BITS
from repro.core.sz import lorenzo as _lor


def subseq_counts(units, dec_sym, dec_len, start_abs, end_abs, total_bits,
                  max_len: int):
    landing, counts = hd.subseq_scan(jnp.asarray(units), dec_sym, dec_len,
                                     start_abs, end_abs, total_bits, max_len)
    return counts, landing


def decode_write_tiles(units, dec_sym, dec_len, start_bits, end_bits, offsets,
                       total_bits, max_len: int, n_out: int, tile_syms: int,
                       ss_max: int):
    return hd.decode_write_tiles(jnp.asarray(units), dec_sym, dec_len,
                                 start_bits, end_bits, offsets, total_bits,
                                 max_len, n_out, tile_syms, ss_max)


def decode_padded_compact(units, dec_sym, dec_len, start_abs, end_abs,
                          total_bits, max_len: int, n_out: int):
    out, counts = hd.decode_write(jnp.asarray(units), dec_sym, dec_len,
                                  start_abs, total_bits, max_len, n_out)
    return out, counts


def selfsync_sync(units, dec_sym, dec_len, total_bits, n_subseq: int,
                  subseqs_per_seq: int, max_len: int):
    units = jnp.asarray(units)
    start, _ = hd.selfsync_intra(units, dec_sym, dec_len, total_bits,
                                 n_subseq, max_len, subseqs_per_seq)
    start, _ = hd.selfsync_inter(units, dec_sym, dec_len, start, total_bits,
                                 max_len, subseqs_per_seq)
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    _, counts = hd.subseq_scan(units, dec_sym, dec_len, start,
                               boundaries + SUBSEQ_BITS, total_bits, max_len)
    return start, counts


def encode_bitpack(symbols, enc_code, enc_len, total_bits: int,
                   subseqs_per_seq: int, min_len: int = 1):
    """Oracle for ``ops.encode_bitpack``: the searchsorted bit
    materialization of the core encoder (``min_len`` only sizes the
    kernel's lane budget, so the oracle ignores it)."""
    del min_len, total_bits
    return he.encode(symbols, enc_code, enc_len,
                     subseqs_per_seq=subseqs_per_seq)


def histogram(x, nbins: int):
    return jnp.bincount(jnp.clip(x.reshape(-1).astype(jnp.int32), 0,
                                 nbins - 1), length=nbins)


def lorenzo_quantize(x, eb, radius: int = 512):
    codes, outlier, resid = _lor.quantize(x, eb, radius=radius)
    return codes.reshape(-1), outlier.reshape(-1), resid.reshape(-1)


def lorenzo_reconstruct(d, eb, shape=None):
    if shape is None:
        shape = d.shape
    q = d.reshape(shape)
    for axis in range(len(shape)):
        q = jnp.cumsum(q, axis=axis)
    return (q.astype(jnp.float32) * jnp.float32(2 * eb)).reshape(-1)
