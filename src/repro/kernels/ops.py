"""jit'd wrappers around the Pallas kernels + the full kernel decode pipeline.

Everything here mirrors a function in ``repro.kernels.ref`` (the pure-jnp
oracle); tests sweep shapes/dtypes and assert exact equality.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he
from repro.core.huffman.bits import SUBSEQ_BITS
from repro.kernels import common as C
from repro.kernels import fused_decode as _fus
from repro.kernels import histogram as _hist
from repro.kernels import huffman_decode as _dec
from repro.kernels import huffman_encode as _enc
from repro.kernels import huffman_selfsync as _sync
from repro.kernels import lorenzo as _lor

# ---------------------------------------------------------------------------
# Metadata prep shared by the decode kernels
# ---------------------------------------------------------------------------


def _subseq_windows(start_abs, end_abs, total_bits):
    """Convert absolute bit windows to (subseq_id, row-local start/end)."""
    start_abs = start_abs.astype(jnp.int32)
    ids = start_abs // SUBSEQ_BITS
    base = ids * SUBSEQ_BITS
    start_local = start_abs - base
    end_local = jnp.clip(jnp.minimum(end_abs, total_bits) - base, 0,
                         C.ROW_UNITS * 32)
    return ids, start_local, end_local


def subseq_counts(units, dec_sym, dec_len, start_abs, end_abs, total_bits,
                  max_len: int, interpret: bool = True):
    ids, start_local, end_local = _subseq_windows(start_abs, end_abs,
                                                  total_bits)
    n = ids.shape[0]
    ss_block = _dec.DEFAULT_SS_BLOCK
    pad = (-n) % ss_block
    if pad:
        ids = jnp.concatenate([ids, jnp.zeros(pad, jnp.int32)])
        start_local = jnp.concatenate([start_local, jnp.zeros(pad, jnp.int32)])
        # start == end => inactive padding lanes
        end_local = jnp.concatenate([end_local, jnp.zeros(pad, jnp.int32)])
    rows = C.gather_subseq_rows(jnp.asarray(units), ids)
    counts, landing = _dec.count_subseq(rows, start_local, end_local,
                                        dec_sym, dec_len, max_len,
                                        interpret=interpret)
    return counts[:n], landing[:n]


def _tile_inputs(units, start_bits, end_bits, offsets, total_bits,
                 n_out: int, tile_syms: int, ss_max: int, lut_base=None):
    """Per-tile lane metadata shared by the plain and fused tile decoders.

    Maps each output tile to the (statically bounded) range of subsequences
    overlapping it and converts their absolute bit windows to row-local
    coordinates.  Returns (rows, start_local, end_local, off_local, lut_tile).
    """
    units = jnp.asarray(units)
    n_subseq = start_bits.shape[0]
    n_tiles = (n_out + tile_syms - 1) // tile_syms
    tile_base = jnp.arange(n_tiles, dtype=jnp.int32) * tile_syms
    s0 = jnp.clip(jnp.searchsorted(offsets, tile_base, side="right") - 1,
                  0, n_subseq - 1)

    lane = jnp.arange(ss_max, dtype=jnp.int32)
    subs_raw = s0[:, None] + lane[None, :]
    valid = subs_raw < n_subseq
    subs = jnp.clip(subs_raw, 0, n_subseq - 1)

    ids, start_local, end_local = _subseq_windows(
        start_bits[subs], end_bits[subs], total_bits)
    # Invalid (clipped) lanes: no work, out-of-tile offset.
    start_local = jnp.where(valid, start_local, 0)
    end_local = jnp.where(valid, end_local, 0)
    off_local = jnp.where(valid, offsets[subs] - tile_base[:, None],
                          tile_syms).astype(jnp.int32)
    if lut_base is None:
        lut_tile = jnp.zeros(subs.shape, jnp.int32)
    else:
        lut_tile = jnp.where(valid, lut_base[subs], 0).astype(jnp.int32)

    rows = C.gather_subseq_rows(units, ids)
    return rows, start_local, end_local, off_local, lut_tile


def decode_write_tiles(units, dec_sym, dec_len, start_bits, end_bits, offsets,
                       total_bits, max_len: int, n_out: int, tile_syms: int,
                       ss_max: int, lut_base=None, interpret: bool = True):
    """Kernel-backed phase 4; signature-compatible with the jnp reference
    ``core.huffman.decode.decode_write_tiles`` (so the tuner can inject it).

    ``lut_base`` (optional int32[n_subseq]) selects a per-subsequence decode
    table inside a merged LUT (the batched multi-tensor path).
    """
    rows, start_local, end_local, off_local, lut_tile = _tile_inputs(
        units, start_bits, end_bits, offsets, total_bits, n_out, tile_syms,
        ss_max, lut_base)
    return _dec.decode_tiles(rows, start_local, end_local, off_local,
                             lut_tile, dec_sym, dec_len, max_len, tile_syms,
                             ss_max, n_out, interpret=interpret)


def _two_eb_f32(eb):
    """The reconstruction scale as a float32[1] kernel input.

    Doubling commutes with float32 rounding (power-of-two scaling), so this
    is bit-identical to the ``2 * eb`` inside ``lorenzo.dequantize``.
    """
    return jnp.asarray(eb, jnp.float32).reshape(1) * 2


def fused_squeeze(shape):
    """Canonical fused-path view of ``shape``: unit axes dropped.

    Cumsum along a unit axis is the identity, so reconstruction over the
    squeezed shape is bitwise the reconstruction over the full shape.  Both
    the eligibility check (``compressor.fused_unsupported_reason``) and the
    kernel dispatch below must agree on this rule.
    """
    if shape is None:
        return None
    sq = tuple(int(s) for s in shape if s != 1)
    return sq if len(sq) > 1 else None


def fused_tile_rows(shape, tile_syms: int) -> int:
    """Rows per tile for the N-D fused kernels.

    ~``tile_syms`` symbols per tile, rounded to whole rows; for 3-D the
    row count must divide the plane height so no tile crosses a plane
    boundary (the row-carry reset happens between tiles).
    """
    plane_rows, cols = shape[-2], shape[-1]
    w = max(1, tile_syms // cols)
    w = min(w, plane_rows)
    if len(shape) == 3:
        while plane_rows % w:
            w -= 1
    return w


def decode_write_tiles_fused(units, dec_sym, dec_len, start_bits, end_bits,
                             offsets, total_bits, max_len: int, n_out: int,
                             tile_syms: int, ss_max: int, opos, oval, eb,
                             radius: int, lut_base=None, shape=None,
                             out_dtype=jnp.float32, interpret: bool = True):
    """Fused phase 4: tile decode + dequantize + inverse-Lorenzo epilogue.

    Same tile mapping as :func:`decode_write_tiles`; the kernel carries the
    decoded symbols through ``2*eb*(cumsum(code - radius))`` (outlier side
    list ``opos``/``oval`` scattered in) without materializing the quant-code
    array.  ``shape`` selects the 2-D/3-D epilogue (row/plane carries in VMEM
    scratch); unit axes are squeezed first, so e.g. ``(1, n)`` still takes
    the 1-D chained-carry kernel.  Returns reconstructed ``out_dtype[n_out]``
    (flat, C-order).
    """
    sq = fused_squeeze(shape)
    out_dtype = jnp.dtype(out_dtype)
    if sq is None:
        rows, start_local, end_local, off_local, lut_tile = _tile_inputs(
            units, start_bits, end_bits, offsets, total_bits, n_out,
            tile_syms, ss_max, lut_base)
        return _fus.decode_tiles_fused(
            rows, start_local, end_local, off_local, lut_tile, dec_sym,
            dec_len, jnp.asarray(opos, jnp.int32),
            jnp.asarray(oval, jnp.int32), _two_eb_f32(eb), max_len,
            tile_syms, ss_max, n_out, radius, out_dtype=out_dtype,
            interpret=interpret)
    # N-D: re-tile along whole rows of the fastest axis.  The tile size
    # changes, so the lane budget must be re-derived for the new tile.
    from repro.core.huffman.pipeline import ss_max_for_tile

    rows_per_tile = fused_tile_rows(sq, tile_syms)
    block = rows_per_tile * sq[-1]
    ss_max_nd = ss_max_for_tile(block, max_len)
    rows, start_local, end_local, off_local, lut_tile = _tile_inputs(
        units, start_bits, end_bits, offsets, total_bits, n_out, block,
        ss_max_nd, lut_base)
    return _fus.decode_tiles_fused_nd(
        rows, start_local, end_local, off_local, lut_tile, dec_sym, dec_len,
        jnp.asarray(opos, jnp.int32), jnp.asarray(oval, jnp.int32),
        _two_eb_f32(eb), max_len, rows_per_tile, sq, ss_max_nd, radius,
        out_dtype=out_dtype, interpret=interpret)


def decode_padded_fused(units, dec_sym, dec_len, start_abs, end_abs,
                        total_bits, max_len: int, n_out: int, opos, oval, eb,
                        radius: int, shape=None, out_dtype=jnp.float32,
                        interpret: bool = True):
    """Fused baseline phase 4: padded decode + the standalone epilogue kernel.

    The padded layout + compaction keeps the original decoders' scattered-
    write cost structure (that is the point of the baseline); the epilogue
    (``fused_decode.dequant_reconstruct`` / ``dequant_reconstruct_nd``) then
    fuses dequantization and reconstruction into one chained-scan kernel
    instead of two jnp passes.
    """
    codes, _ = decode_padded_compact(units, dec_sym, dec_len, start_abs,
                                     end_abs, total_bits, max_len, n_out,
                                     interpret=interpret)
    out_dtype = jnp.dtype(out_dtype)
    sq = fused_squeeze(shape)
    if sq is None:
        block = 4096
        pad = (-n_out) % block
        if pad:
            codes = jnp.concatenate([codes, jnp.zeros(pad, jnp.uint16)])
        out = _fus.dequant_reconstruct(codes, jnp.asarray(opos, jnp.int32),
                                       jnp.asarray(oval, jnp.int32),
                                       _two_eb_f32(eb), radius,
                                       out_dtype=out_dtype,
                                       interpret=interpret)
        return out[:n_out]
    rows_per_tile = fused_tile_rows(sq, 4096)
    block = rows_per_tile * sq[-1]
    pad = (-n_out) % block
    if pad:
        codes = jnp.concatenate([codes, jnp.zeros(pad, jnp.uint16)])
    return _fus.dequant_reconstruct_nd(
        codes, jnp.asarray(opos, jnp.int32), jnp.asarray(oval, jnp.int32),
        _two_eb_f32(eb), radius, sq, rows_per_tile, out_dtype=out_dtype,
        interpret=interpret)


def decode_padded_compact(units, dec_sym, dec_len, start_abs, end_abs,
                          total_bits, max_len: int, n_out: int,
                          interpret: bool = True):
    """Kernel-backed baseline phase 4 (padded layout + ops-level compaction).

    Reproduces the original decoders' scattered-write cost structure."""
    ids, start_local, end_local = _subseq_windows(start_abs, end_abs,
                                                  total_bits)
    n = ids.shape[0]
    ss_block = _dec.DEFAULT_SS_BLOCK
    pad = (-n) % ss_block
    if pad:
        z = jnp.zeros(pad, jnp.int32)
        ids, start_local, end_local = (jnp.concatenate([ids, z]),
                                       jnp.concatenate([start_local, z]),
                                       jnp.concatenate([end_local, z]))
    rows = C.gather_subseq_rows(jnp.asarray(units), ids)
    padded, counts = _dec.decode_padded(rows, start_local, end_local,
                                        dec_sym, dec_len, max_len,
                                        interpret=interpret)
    padded, counts = padded[:n], counts[:n]
    offsets = hd.output_offsets(counts)
    out_pos = jnp.arange(n_out, dtype=jnp.int32)
    owner = jnp.clip(jnp.searchsorted(offsets, out_pos, side="right") - 1,
                     0, n - 1)
    within = out_pos - offsets[owner]
    return padded[owner, jnp.clip(within, 0, C.MAX_SYMS - 1)], counts


def selfsync_sync(units, dec_sym, dec_len, total_bits, n_subseq: int,
                  subseqs_per_seq: int, max_len: int,
                  early_exit: bool = True, interpret: bool = True):
    """Kernel-backed sync discovery: intra-sequence kernel + inter-sequence
    head chaining (phases 1+2).  Returns (start_abs, counts, stats)."""
    units = jnp.asarray(units)
    n_seq = n_subseq // subseqs_per_seq
    boundaries = jnp.arange(n_subseq, dtype=jnp.int32) * SUBSEQ_BITS
    ids = jnp.arange(n_subseq, dtype=jnp.int32)
    rows = C.gather_subseq_rows(units, ids).reshape(
        n_seq, subseqs_per_seq, C.ROW_UNITS)
    end_local = jnp.clip(
        jnp.minimum(boundaries + SUBSEQ_BITS, total_bits) - boundaries,
        0, C.ROW_UNITS * 32).reshape(n_seq, subseqs_per_seq)

    run = partial(_sync.selfsync_intra, rows, end_local=end_local,
                  dec_sym=dec_sym, dec_len=dec_len, max_len=max_len,
                  subseqs_per_seq=subseqs_per_seq, early_exit=early_exit,
                  interpret=interpret)

    def one_pass(heads):
        start, counts, landing, rounds = run(heads=heads)
        # Landing of each sequence's last lane seeds the next sequence.
        new_heads = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), landing[:-1, -1] - 128])[:, None]
        return start, counts, rounds, new_heads

    heads = jnp.zeros((n_seq, 1), jnp.int32)
    start, counts, rounds, new_heads = one_pass(heads)
    total_rounds = rounds

    def cond(state):
        heads, new_heads, *_ = state
        return jnp.any(heads != new_heads)

    def body(state):
        _, heads, start, counts, total_rounds = state
        start, counts, rounds, new_heads = one_pass(heads)
        return heads, new_heads, start, counts, total_rounds + rounds

    _, _, start, counts, total_rounds = jax.lax.while_loop(
        cond, body, (heads, new_heads, start, counts, total_rounds))

    start_abs = boundaries + start.reshape(-1)
    return start_abs, counts.reshape(-1), total_rounds


# ---------------------------------------------------------------------------
# Encode bit-pack (write-path phase 4)
# ---------------------------------------------------------------------------

DEFAULT_ENCODE_TILE_UNITS = 8


@partial(jax.jit, static_argnames=("n_units_padded", "subseqs_per_seq",
                                   "min_len", "tile_units", "interpret"))
def _encode_bitpack_padded(symbols, enc_code, enc_len, n_units_padded: int,
                           subseqs_per_seq: int, min_len: int,
                           tile_units: int, interpret: bool):
    """Traced body of :func:`encode_bitpack` (sizes fixed for jit)."""
    symbols = symbols.astype(jnp.int32)
    lens = jnp.asarray(enc_len)[symbols].astype(jnp.int32)
    starts = jnp.cumsum(lens) - lens               # exclusive scan [N]
    codes = jnp.asarray(enc_code)[symbols].astype(jnp.uint32)
    total_bits = (starts[-1] + lens[-1]).astype(jnp.int32)
    n = symbols.shape[0]

    # --- tile -> symbol mapping (mirrors the decode kernels' prep) -----
    tile_bits = tile_units * 32
    n_tiles = n_units_padded // tile_units
    # Lane budget: starts inside the tile are >= min_len apart, plus the
    # (at most one) codeword crossing in from the left.
    sym_max = tile_bits // max(min_len, 1) + 2
    tile_base = jnp.arange(n_tiles, dtype=jnp.int32) * tile_bits
    s0 = jnp.clip(jnp.searchsorted(starts, tile_base, side="right") - 1,
                  0, n - 1)
    lane = jnp.arange(sym_max, dtype=jnp.int32)
    idx_raw = s0[:, None] + lane[None, :]
    idx = jnp.clip(idx_raw, 0, n - 1)
    st = starts[idx]
    ln = lens[idx]
    overlaps = ((idx_raw < n)
                & (st < tile_base[:, None] + tile_bits)
                & (st + ln > tile_base[:, None]))
    tile_len = jnp.where(overlaps, ln, 0)
    tile_start = st - tile_base[:, None]
    units = _enc.pack_tiles(codes[idx], tile_len, tile_start,
                            n_units_padded, tile_units, sym_max,
                            interpret=interpret)

    gaps, counts, seq_counts = he.stream_metadata(
        starts, total_bits, n_units_padded, subseqs_per_seq)
    return he.EncodedStream(
        units=units, gaps=gaps, counts=counts, seq_counts=seq_counts,
        total_bits=total_bits,
        n_symbols=jnp.asarray(n, jnp.int32),
        subseqs_per_seq=subseqs_per_seq)


def encode_bitpack(symbols, enc_code, enc_len, total_bits: int,
                   subseqs_per_seq: int, min_len: int = 1,
                   tile_units: int = DEFAULT_ENCODE_TILE_UNITS,
                   interpret: bool = True) -> he.EncodedStream:
    """Kernel-backed Huffman encode: per-tile prefix-sum bit placement.

    ``total_bits`` is the exact payload size (the ``EncoderPlan`` derives
    it from the histogram, so the symbol array never round-trips to host);
    ``min_len`` (the codebook's shortest codeword) bounds the static lane
    budget.  Layout is bit-identical to ``core.huffman.encode.encode``.
    """
    symbols = jnp.asarray(symbols)
    if symbols.shape[0] == 0:
        return he.empty_stream(subseqs_per_seq)
    n_units_padded = he.units_for_bits(total_bits, subseqs_per_seq)
    return _encode_bitpack_padded(symbols, jnp.asarray(enc_code),
                                  jnp.asarray(enc_len), n_units_padded,
                                  subseqs_per_seq, min_len, tile_units,
                                  interpret)


# ---------------------------------------------------------------------------
# Histogram + Lorenzo wrappers
# ---------------------------------------------------------------------------

histogram = _hist.histogram


def lorenzo_quantize(x, eb, radius: int = 512, interpret: bool = True):
    """N-D dual-quant Lorenzo via the 1-D kernel applied per axis.

    For 1-D inputs this is a single kernel launch; N-D composes the exact
    integer finite-difference per axis at the ops level (the round-to-lattice
    happens once, inside the kernel, along the innermost axis pass).
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = 4096
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, flat.dtype)])
    if len(shape) == 1:
        codes, outlier, resid = _lor.quantize1d(flat, float(eb), radius=radius,
                                                interpret=interpret)
        return codes[:n], outlier[:n].astype(bool), resid[:n]
    # N-D: lattice quantize via kernel's rounding on the flat view, then the
    # exact multi-axis finite difference in jnp (integer, exact).
    from repro.core.sz import lorenzo as _ref

    return _ref.quantize(x, eb, radius=radius)


def lorenzo_reconstruct(d, eb, shape=None, interpret: bool = True):
    """Inverse Lorenzo; 1-D uses the chained-scan kernel."""
    if shape is None or len(shape) == 1:
        n = d.shape[0]
        block = 4096
        pad = (-n) % block
        dd = jnp.concatenate([d, jnp.zeros(pad, d.dtype)]) if pad else d
        out = _lor.reconstruct1d(dd, float(eb), interpret=interpret)
        return out[:n]
    q = d.reshape(shape)
    for axis in range(len(shape)):
        q = jnp.cumsum(q, axis=axis)
    return q.astype(jnp.float32) * jnp.float32(2 * eb)
