"""Pallas TPU kernels for parallel Huffman decoding (gap-array phases).

Two kernels:

  * ``count_kernel`` -- phase 1 ("get output idx."): each lane decodes its
    subsequence window and counts codeword starts.  Grid over blocks of
    ``SS_BLOCK`` subsequences; each block's unit rows live in VMEM.

  * ``decode_tiles_kernel`` -- phase 2 (paper Alg. 1): grid over *output*
    tiles of ``tile_syms`` symbols.  Each step decodes the statically bounded
    set of subsequences overlapping its tile into a VMEM staging buffer and
    emits one dense, aligned tile -- the TPU analogue of the shared-memory
    staged coalesced write.  ``tile_syms`` is the tunable the online tuner
    (core/huffman/pipeline.py) selects per compression-ratio class; the
    per-lane ``lut_base`` input selects a codebook inside a merged decode
    LUT for the batched multi-tensor path.

TPU notes: the in-kernel gather (LUT lookup, per-lane unit fetch) lowers to
Mosaic dynamic-gather over VMEM; the local scatter into the staging tile is
a vector scatter confined to VMEM.  Validated in interpret mode (this
container is CPU-only); BlockSpecs are written for real VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common as C

DEFAULT_SS_BLOCK = 256   # subsequences per count-kernel block


def count_kernel_body(rows_ref, start_ref, end_ref, sym_ref, len_ref,
                      counts_ref, land_ref, *, max_len):
    rows = rows_ref[...]
    start = start_ref[...]
    end = end_ref[...]
    dec_sym = sym_ref[...]
    dec_len = len_ref[...]
    landing, counts = C.decode_window(rows, start, end, dec_sym, dec_len,
                                      max_len, collect=False)
    counts_ref[...] = counts
    land_ref[...] = landing


@functools.partial(
    jax.jit, static_argnames=("max_len", "ss_block", "interpret"))
def count_subseq(rows, start_local, end_local, dec_sym, dec_len,
                 max_len: int, ss_block: int = DEFAULT_SS_BLOCK,
                 interpret: bool = True):
    """Per-subsequence codeword counts + landing positions.

    rows: uint32[n_subseq, ROW_UNITS]; start/end_local: int32[n_subseq]
    (row-local bit windows).  Returns (counts, landing) int32[n_subseq].
    """
    n = rows.shape[0]
    assert n % ss_block == 0, (n, ss_block)
    grid = (n // ss_block,)
    lut = dec_sym.shape[0]
    kernel = functools.partial(count_kernel_body, max_len=max_len)
    counts, landing = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ss_block, C.ROW_UNITS), lambda b: (b, 0)),
            pl.BlockSpec((ss_block,), lambda b: (b,)),
            pl.BlockSpec((ss_block,), lambda b: (b,)),
            pl.BlockSpec((lut,), lambda b: (0,)),
            pl.BlockSpec((lut,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ss_block,), lambda b: (b,)),
            pl.BlockSpec((ss_block,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(rows, start_local, end_local, dec_sym, dec_len)
    return counts, landing


def decode_tiles_kernel_body(rows_ref, start_ref, end_ref, off_ref, lut_ref,
                             sym_ref, len_ref, out_ref, *, max_len,
                             tile_syms):
    # VMEM staging: each lane decodes its window and scatters its symbols to
    # tile-local positions (C.stage_tile); one dense aligned tile comes out.
    out_ref[0] = C.stage_tile(
        rows_ref[0],              # (ss_max, ROW_UNITS)
        start_ref[0],             # (ss_max,) row-local start bits
        end_ref[0],               # (ss_max,)
        off_ref[0],               # (ss_max,) tile-local output offsets
        lut_ref[0],               # (ss_max,) per-lane LUT base offsets
        sym_ref[...], len_ref[...], max_len, tile_syms)


@functools.partial(
    jax.jit,
    static_argnames=("max_len", "tile_syms", "ss_max", "n_out", "interpret"))
def decode_tiles(rows, start_local, end_local, off_local, lut_base, dec_sym,
                 dec_len, max_len: int, tile_syms: int, ss_max: int,
                 n_out: int, interpret: bool = True):
    """Tile-centric decode+write.

    rows:        uint32[n_tiles, ss_max, ROW_UNITS]
    start/end:   int32[n_tiles, ss_max]   (row-local windows)
    off_local:   int32[n_tiles, ss_max]   (output offset - tile base;
                 invalid lanes carry ``tile_syms``)
    lut_base:    int32[n_tiles, ss_max]   (per-lane offset into a merged
                 decode LUT; all-zero for single-codebook decodes)
    Returns uint16[n_out].
    """
    n_tiles = rows.shape[0]
    lut = dec_sym.shape[0]
    kernel = functools.partial(decode_tiles_kernel_body, max_len=max_len,
                               tile_syms=tile_syms)
    tiles = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, ss_max, C.ROW_UNITS), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((lut,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile_syms), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_syms), jnp.uint16),
        interpret=interpret,
    )(rows, start_local, end_local, off_local, lut_base, dec_sym, dec_len)
    return tiles.reshape(-1)[:n_out]


def decode_padded_kernel_body(rows_ref, start_ref, end_ref, sym_ref, len_ref,
                              out_ref, counts_ref, *, max_len):
    """Baseline decode+write without staging: emits the padded
    (subseq, MAX_SYMS) layout that ops-level compaction then gathers --
    the structural analogue of the original decoders' uncoalesced writes."""
    rows = rows_ref[...]
    start = start_ref[...]
    end = end_ref[...]
    _, counts, padded = C.decode_window(rows, start, end, sym_ref[...],
                                        len_ref[...], max_len, collect=True)
    out_ref[...] = padded
    counts_ref[...] = counts


@functools.partial(
    jax.jit, static_argnames=("max_len", "ss_block", "interpret"))
def decode_padded(rows, start_local, end_local, dec_sym, dec_len,
                  max_len: int, ss_block: int = DEFAULT_SS_BLOCK,
                  interpret: bool = True):
    n = rows.shape[0]
    assert n % ss_block == 0
    lut = dec_sym.shape[0]
    kernel = functools.partial(decode_padded_kernel_body, max_len=max_len)
    padded, counts = pl.pallas_call(
        kernel,
        grid=(n // ss_block,),
        in_specs=[
            pl.BlockSpec((ss_block, C.ROW_UNITS), lambda b: (b, 0)),
            pl.BlockSpec((ss_block,), lambda b: (b,)),
            pl.BlockSpec((ss_block,), lambda b: (b,)),
            pl.BlockSpec((lut,), lambda b: (0,)),
            pl.BlockSpec((lut,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ss_block, C.MAX_SYMS), lambda b: (b, 0)),
            pl.BlockSpec((ss_block,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, C.MAX_SYMS), jnp.uint16),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(rows, start_local, end_local, dec_sym, dec_len)
    return padded, counts
