"""Pallas bit-pack emit kernel: the write-path twin of the decode kernels.

The decode side turned the paper's phases into kernels; this module does
the same for phase 4 of the *encoder* (DESIGN.md §9 stream layout).  The
host encoder materializes every output bit with a ``searchsorted`` over
codeword start positions; here each grid step owns one ``tile_units``-word
output tile and the symbols overlapping it are gathered up front (ops-level
metadata prep, exactly like the decode kernels' tile->subsequence mapping):

* A per-tile prefix-sum over code lengths (the exclusive ``starts`` scan,
  computed once on device) places each symbol's first bit; the lane budget
  ``sym_max`` is static -- at most one codeword crosses into the tile from
  the left plus ``tile_bits // min_len`` starts inside it.
* Each lane splits its (<= 32-bit, so at most unit-spanning) codeword into
  the two uint32 words it touches with shift arithmetic, then a vector
  scatter-ADD accumulates the tile.  Codeword bit ranges are disjoint, so
  add IS or -- the writes are atomic-free by construction.
* Out-of-tile halves (the left-crosser's high word, the right edge's low
  word) are dropped; the neighbouring tiles emit those bits from their own
  view of the same symbols.  No cross-tile carries, no sequential grid.

The jnp oracle is ``core.huffman.encode._encode_padded`` (the bit
materialization path); tests assert byte-identical units across backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(code_ref, len_ref, start_ref, out_ref, *, tile_units):
    code = code_ref[0, :].astype(jnp.uint32)
    length = len_ref[0, :]                    # int32; 0 => inactive lane
    p = start_ref[0, :]                       # tile-local first-bit position
    # p may be negative (codeword crossing in from the previous tile):
    # arithmetic shift / mask give the floor unit and in-unit offset.
    u = p >> 5
    o = p & 31

    # Left-align the codeword in the 64-bit window starting at unit u:
    # value64 = code << (64 - o - length); hi lands in unit u, lo in u + 1.
    shift = 64 - o - length                   # in [1, 63] for active lanes
    hi = jnp.where(
        shift >= 32,
        code << jnp.clip(shift - 32, 0, 31).astype(jnp.uint32),
        code >> jnp.clip(32 - shift, 0, 31).astype(jnp.uint32),
    )
    lo = jnp.where(
        shift >= 32, jnp.uint32(0),
        # uint32 << keeps the low 32 bits -- exactly value64 & 0xffffffff.
        code << jnp.clip(shift, 0, 31).astype(jnp.uint32),
    )
    active = length > 0
    hi = jnp.where(active, hi, jnp.uint32(0))
    lo = jnp.where(active, lo, jnp.uint32(0))

    # Scatter-add == scatter-or (disjoint bit ranges).  mode="drop" sheds
    # the halves owned by neighbouring tiles; a negative u must be routed
    # out the HIGH side first (negative indices would wrap, not drop).
    u_hi = jnp.where(u >= 0, u, tile_units)
    units = jnp.zeros((tile_units,), jnp.uint32)
    units = units.at[u_hi].add(hi, mode="drop")
    units = units.at[u + 1].add(lo, mode="drop")
    out_ref[...] = units


@functools.partial(
    jax.jit,
    static_argnames=("n_units_padded", "tile_units", "sym_max", "interpret"))
def pack_tiles(tile_code, tile_len, tile_start, n_units_padded: int,
               tile_units: int, sym_max: int, interpret: bool = True):
    """Emit the packed uint32 units from per-tile gathered symbol metadata.

    ``tile_code`` / ``tile_len`` / ``tile_start`` are (n_tiles, sym_max)
    arrays built by ``repro.kernels.ops.encode_bitpack``: the codewords
    overlapping each tile, their lengths (0 for inactive lanes) and their
    tile-local start bit (negative for the left-crossing codeword).
    """
    n_tiles = n_units_padded // tile_units
    return pl.pallas_call(
        functools.partial(_pack_kernel, tile_units=tile_units),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, sym_max), lambda i: (i, 0)),
            pl.BlockSpec((1, sym_max), lambda i: (i, 0)),
            pl.BlockSpec((1, sym_max), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_units,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_units_padded,), jnp.uint32),
        interpret=interpret,
    )(tile_code, tile_len, tile_start)
