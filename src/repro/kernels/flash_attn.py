"""Pallas flash-attention forward kernel (§Perf hillclimb D).

The XLA blockwise attention (models/attention.py) materializes every
(chunk, S_kv) f32 score block to HBM at fusion boundaries — the largest
single traffic class of every dense train/prefill cell in the §Roofline
table.  This kernel keeps scores, running max/sum and the output
accumulator in VMEM scratch; HBM traffic collapses to Q/K/V reads + O
writes:

  before (per layer, per pass): ~4 * B*H*S*S_kv * 4 B   (scores + exp)
  after:                         (2*B*H*S*D + 2*B*H*S_kv*D) * 2 B

Grid: (B*H, n_q_blocks, n_kv_blocks) with the kv dimension innermost and
sequential; (m, l, acc) scratch carries across kv steps (the standard
flash recurrence).  Causal masking is applied per element from absolute
block offsets; fully-masked kv blocks are skipped via @pl.when (the
`__all_sync`-style early exit at block granularity).

Forward-only: the backward runs the XLA path (jax.checkpoint already gives
it flash-like *memory*; traffic parity needs a bwd kernel — listed as
future work).  Validated in interpret mode against the blockwise oracle
(tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  causal: bool, block_q: int, block_k: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip kv blocks strictly above the causal diagonal
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(jnp.bool_(run) if isinstance(run, bool) else run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, dv)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + p @ v
        m_s[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BH, Skv, D|Dv).  Returns (BH, Sq, Dv)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (bh, sq // block_q, skv // block_k)
    scale = d ** -0.5

    kernel = functools.partial(_flash_kernel, causal=causal,
                               block_q=block_q, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum
            pltpu.VMEM((block_q, dv), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def hbm_bytes_xla(b, h, sq, skv, d, passes=3):
    """Score-block traffic of the XLA blockwise path (f32 scores + exp)."""
    return 4 * b * h * sq * skv * 4 * passes


def hbm_bytes_kernel(b, h, sq, skv, d, passes=3):
    """Q/K/V in + O out for the kernel (bf16)."""
    return (2 * b * h * sq * d + 2 * b * h * skv * d) * 2 * passes


# ---------------------------------------------------------------------------
# Training integration: kernel forward + recomputed XLA backward
# ---------------------------------------------------------------------------


def _xla_attention(q, k, v, causal: bool):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask, s, NEG_INF)
    return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1),
                      v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_trainable(q, k, v, causal: bool = True,
                              block_q: int = 128, block_k: int = 128):
    """Differentiable wrapper: Pallas flash forward, recomputed XLA backward.

    The backward re-derives the softmax from (q, k, v) -- flash-style
    memory (no saved score blocks) with XLA compute; a fused backward
    kernel is the listed next step."""
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k)


def _fwd(q, k, v, causal, block_q, block_k):
    out = flash_attention(q, k, v, causal=causal, block_q=block_q,
                          block_k=block_k)
    return out, (q, k, v)


def _bwd(causal, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _xla_attention(q_, k_, v_, causal),
                     q, k, v)
    return vjp(g)


flash_attention_trainable.defvjp(_fwd, _bwd)
