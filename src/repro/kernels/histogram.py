"""Pallas histogram kernel (Gomez-Luna-style, as used by cuSZ).

Used twice in the pipeline: (a) quantization-code frequencies for codebook
construction, (b) compression-ratio class counts for the online tuner
(paper Alg. 2 step 2).

Grid over symbol chunks; a privatized VMEM accumulator (the analogue of the
per-block shared-memory sub-histogram) is updated with a vector scatter-add
and flushed into the single output block, which Pallas keeps resident across
the sequential grid ("arbitrary" dimension semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(x_ref, out_ref, *, nbins):
    chunk = x_ref[...].astype(jnp.int32).reshape(-1)
    local = jnp.zeros((nbins,), jnp.int32).at[
        jnp.clip(chunk, 0, nbins - 1)].add(1)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += local


@functools.partial(
    jax.jit, static_argnames=("nbins", "chunk", "interpret"))
def histogram(x, nbins: int, chunk: int = 65536, interpret: bool = True):
    """int histogram of ``x`` (any int dtype, values clipped to [0, nbins))."""
    x = x.reshape(-1).astype(jnp.int32)
    n = x.shape[0]
    pad = (-n) % chunk
    if pad:
        # Out-of-range marker: count into the last bin then subtract.
        x = jnp.concatenate([x, jnp.full((pad,), nbins - 1, jnp.int32)])
    grid = (x.shape[0] // chunk,)
    hist = pl.pallas_call(
        functools.partial(_hist_kernel, nbins=nbins),
        grid=grid,
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), jnp.int32),
        interpret=interpret,
    )(x)
    if pad:
        hist = hist.at[nbins - 1].add(-pad)
    return hist
