"""Pallas TPU kernels for the fused decode→dequantize→reconstruct path.

The two-pass decompression pipeline materializes the full uint16
quantization-code array in HBM between the Huffman decode-write kernel and
the Lorenzo reconstruction kernel.  The paper's core lesson (§IV) is that
the decoder is memory-bound, so that round trip is pure overhead: these
kernels carry the decoded symbols straight through dequantization
(``d = code - radius`` with the outlier side list scattered in) and the
inverse-Lorenzo prefix sum (``x = 2·eb · cumsum(d)``) inside the same
dispatch, emitting float32 output tiles and never writing the code array
back to HBM.

Two kernels:

  * ``decode_tiles_fused`` -- ``huffman_decode.decode_tiles_kernel_body``
    plus the dequantize/reconstruct epilogue.  The grid runs over output
    tiles; TPU grids execute sequentially, so the Lorenzo carry (the
    running prefix sum at each tile boundary) lives in a VMEM scratch
    exactly as in ``lorenzo._recon_kernel``.

  * ``dequant_reconstruct`` -- the epilogue alone (``lorenzo._recon_kernel``
    extended with dequantization and the outlier scatter), chained after
    the padded baseline decoder so every decode-write strategy has a fused
    form.

Bit-exactness: the carry-chained per-tile ``cumsum`` is int32 integer
arithmetic, identical to the monolithic ``jnp.cumsum`` of
``core.sz.lorenzo.dequantize``; the single float operation
(``q_f32 * two_eb``) is the same op in both paths, so fused output is
bit-identical to two-pass output.  Validated in interpret mode (this
container is CPU-only); BlockSpecs are written for real VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as C


def _dequant_recon_block(tile_u16, base, opos, oval, carry, two_eb, *,
                         radius: int, block: int):
    """Shared epilogue: one ``block``-symbol tile of codes -> float32.

    ``base`` is the tile's global output offset; ``opos``/``oval`` are the
    full (-1-padded) outlier side list, scattered only where a position
    lands inside this tile; ``carry`` is the VMEM running-prefix scratch.
    Returns the float32 tile and updates ``carry`` in place.
    """
    d = tile_u16.astype(jnp.int32) - radius
    loc = opos - base
    hit = (opos >= 0) & (loc >= 0) & (loc < block)
    d = d.at[jnp.where(hit, loc, block)].set(
        jnp.where(hit, oval, 0), mode="drop")
    q = jnp.cumsum(d) + carry[0]
    carry[0] = q[-1]
    return q.astype(jnp.float32) * two_eb


def decode_tiles_fused_kernel_body(rows_ref, start_ref, end_ref, off_ref,
                                   lut_ref, sym_ref, len_ref, opos_ref,
                                   oval_ref, teb_ref, out_ref, carry, *,
                                   max_len, tile_syms, radius):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry[0] = jnp.int32(0)

    tile = C.stage_tile(rows_ref[0], start_ref[0], end_ref[0], off_ref[0],
                        lut_ref[0], sym_ref[...], len_ref[...], max_len,
                        tile_syms)
    base = pl.program_id(0) * tile_syms
    out_ref[0] = _dequant_recon_block(tile, base, opos_ref[...],
                                      oval_ref[...], carry, teb_ref[0],
                                      radius=radius, block=tile_syms)


@functools.partial(
    jax.jit,
    static_argnames=("max_len", "tile_syms", "ss_max", "n_out", "radius",
                     "interpret"))
def decode_tiles_fused(rows, start_local, end_local, off_local, lut_base,
                       dec_sym, dec_len, opos, oval, two_eb, max_len: int,
                       tile_syms: int, ss_max: int, n_out: int, radius: int,
                       interpret: bool = True):
    """Tile-centric decode+write with the fused dequant/reconstruct epilogue.

    First seven inputs are exactly ``huffman_decode.decode_tiles``; the
    epilogue inputs are ``opos``/``oval`` (the -1-padded outlier side list,
    int32[m_pad]) and ``two_eb`` (float32[1], the reconstruction scale).
    Output positions past ``n_out`` in the final tile decode as zero codes
    and would corrupt the carry, but no tile follows, so the sliced result
    is exact.  Returns float32[n_out].
    """
    n_tiles = rows.shape[0]
    lut = dec_sym.shape[0]
    m = opos.shape[0]
    kernel = functools.partial(decode_tiles_fused_kernel_body,
                               max_len=max_len, tile_syms=tile_syms,
                               radius=radius)
    tiles = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, ss_max, C.ROW_UNITS), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((m,), lambda t: (0,)),
            pl.BlockSpec((m,), lambda t: (0,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile_syms), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_syms), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(rows, start_local, end_local, off_local, lut_base, dec_sym, dec_len,
      opos, oval, two_eb)
    return tiles.reshape(-1)[:n_out]


def dequant_recon_kernel_body(codes_ref, opos_ref, oval_ref, teb_ref,
                              out_ref, carry, *, radius, block):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry[0] = jnp.int32(0)

    base = pl.program_id(0) * block
    out_ref[...] = _dequant_recon_block(codes_ref[...], base, opos_ref[...],
                                        oval_ref[...], carry, teb_ref[0],
                                        radius=radius, block=block)


@functools.partial(
    jax.jit, static_argnames=("radius", "block", "interpret"))
def dequant_reconstruct(codes, opos, oval, two_eb, radius: int,
                        block: int = 4096, interpret: bool = True):
    """Standalone fused epilogue: uint16 codes -> reconstructed float32.

    ``lorenzo.reconstruct1d`` extended with dequantization (``- radius``)
    and the outlier scatter; chained after the padded baseline decoder.
    ``codes`` must be padded to a ``block`` multiple (pad codes decode past
    the real output and only pollute the final block's tail).
    """
    n = codes.shape[0]
    assert n % block == 0, (n, block)
    m = opos.shape[0]
    kernel = functools.partial(dequant_recon_kernel_body, radius=radius,
                               block=block)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(codes, opos, oval, two_eb)
