"""Pallas TPU kernels for the fused decode→dequantize→reconstruct path.

The two-pass decompression pipeline materializes the full uint16
quantization-code array in HBM between the Huffman decode-write kernel and
the Lorenzo reconstruction kernel.  The paper's core lesson (§IV) is that
the decoder is memory-bound, so that round trip is pure overhead: these
kernels carry the decoded symbols straight through dequantization
(``d = code - radius`` with the outlier side list scattered in) and the
inverse-Lorenzo prefix sum (``x = 2·eb · cumsum(d)``) inside the same
dispatch, emitting float32 output tiles and never writing the code array
back to HBM.

Kernel families:

  * ``decode_tiles_fused`` -- ``huffman_decode.decode_tiles_kernel_body``
    plus the dequantize/reconstruct epilogue for flat (1-D Lorenzo)
    output.  The grid runs over output tiles; TPU grids execute
    sequentially, so the Lorenzo carry (the running prefix sum at each
    tile boundary) lives in a VMEM scratch exactly as in
    ``lorenzo._recon_kernel``.

  * ``decode_tiles_fused_nd`` -- the same decode stage with the 2-D/3-D
    inverse-Lorenzo epilogue.  Tiles are whole rows along the fastest
    axis (``rows_per_tile`` rows of ``C`` symbols); the 1-D scalar carry
    generalizes to a ``(C,)`` row carry (the prefix sum over completed
    rows, reset at each plane boundary) and, for 3-D, an ``(R, C)`` plane
    carry (the prefix sum over completed planes), both in VMEM scratch.

  * ``dequant_reconstruct`` / ``dequant_reconstruct_nd`` -- the epilogue
    alone (``lorenzo._recon_kernel`` extended with dequantization and the
    outlier scatter), chained after the padded baseline decoder so every
    decode-write strategy has a fused form at every supported ndim.

Bit-exactness: the carry-chained per-tile ``cumsum`` chain is int32
integer arithmetic, identical to the monolithic per-axis ``jnp.cumsum``
of ``core.sz.lorenzo.dequantize``; the float epilogue computes
``q_f32 * two_eb`` in float32 and casts ONCE to the output dtype -- the
same op order ``lorenzo.dequantize`` uses -- so fused output is
bit-identical to two-pass output for float32 and for bf16/f16.
Validated in interpret mode (this container is CPU-only); BlockSpecs are
written for real VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as C


def _dequant_block(tile_u16, base, opos, oval, *, radius: int, block: int):
    """Dequantize one ``block``-symbol tile of codes to int32 residuals.

    ``base`` is the tile's global output offset; ``opos``/``oval`` are the
    full (-1-padded) outlier side list, scattered only where a position
    lands inside this tile.
    """
    d = tile_u16.astype(jnp.int32) - radius
    loc = opos - base
    hit = (opos >= 0) & (loc >= 0) & (loc < block)
    return d.at[jnp.where(hit, loc, block)].set(
        jnp.where(hit, oval, 0), mode="drop")


def _dequant_recon_block(tile_u16, base, opos, oval, carry, two_eb, *,
                         radius: int, block: int, out_dtype=jnp.float32):
    """Shared 1-D epilogue: one ``block``-symbol tile of codes -> floats.

    ``carry`` is the VMEM running-prefix scratch.  The product runs in
    float32 with one final cast to ``out_dtype`` (see module docstring);
    returns the reconstructed tile and updates ``carry`` in place.
    """
    d = _dequant_block(tile_u16, base, opos, oval, radius=radius,
                       block=block)
    q = jnp.cumsum(d) + carry[0]
    carry[0] = q[-1]
    return (q.astype(jnp.float32) * two_eb).astype(out_dtype)


def _recon_rows_block(d, t, row_carry, plane_carry, two_eb, *,
                      rows_per_tile: int, plane_rows: int, cols: int,
                      planes: int, out_dtype):
    """Shared N-D epilogue: ``rows_per_tile`` dequantized rows -> floats.

    ``d`` is the int32 residual tile (``rows_per_tile * cols`` flat); ``t``
    is the grid step.  The inverse Lorenzo is the per-axis cumsum chain:
    within the tile ``cumsum`` runs along the row (axis -1) and then down
    the rows (axis -2); across tiles the sequential grid carries

      * ``row_carry``   (cols,) int32 -- the prefix sum over all completed
        rows of the current plane (``q`` of the previous tile's last row),
        reset at every plane start;
      * ``plane_carry`` (plane_rows, cols) int32 -- the prefix sum over
        completed planes (3-D only; tiles never cross a plane boundary
        because ``rows_per_tile`` divides ``plane_rows``).

    Trailing fake rows of a final partial tile (2-D) sit strictly after
    every valid output row; the cumsums are directional and no later tile
    reads the polluted carry, so the sliced result is exact.
    """
    @pl.when(t == 0)
    def _init():
        row_carry[...] = jnp.zeros((cols,), jnp.int32)
        if planes > 1:
            plane_carry[...] = jnp.zeros((plane_rows, cols), jnp.int32)

    d2 = d.reshape(rows_per_tile, cols)
    e = jnp.cumsum(d2, axis=1)
    if planes > 1:
        r0 = (t * rows_per_tile) % plane_rows

        @pl.when(r0 == 0)
        def _plane_start():
            row_carry[...] = jnp.zeros((cols,), jnp.int32)

        f = jnp.cumsum(e, axis=0) + row_carry[...][None, :]
        row_carry[...] = f[rows_per_tile - 1]
        q = f + plane_carry[pl.ds(r0, rows_per_tile), :]
        plane_carry[pl.ds(r0, rows_per_tile), :] = q
    else:
        q = jnp.cumsum(e, axis=0) + row_carry[...][None, :]
        row_carry[...] = q[rows_per_tile - 1]
    out = (q.astype(jnp.float32) * two_eb).astype(out_dtype)
    return out.reshape(rows_per_tile * cols)


def decode_tiles_fused_kernel_body(rows_ref, start_ref, end_ref, off_ref,
                                   lut_ref, sym_ref, len_ref, opos_ref,
                                   oval_ref, teb_ref, out_ref, carry, *,
                                   max_len, tile_syms, radius,
                                   out_dtype=jnp.float32):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry[0] = jnp.int32(0)

    tile = C.stage_tile(rows_ref[0], start_ref[0], end_ref[0], off_ref[0],
                        lut_ref[0], sym_ref[...], len_ref[...], max_len,
                        tile_syms)
    base = pl.program_id(0) * tile_syms
    out_ref[0] = _dequant_recon_block(tile, base, opos_ref[...],
                                      oval_ref[...], carry, teb_ref[0],
                                      radius=radius, block=tile_syms,
                                      out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("max_len", "tile_syms", "ss_max", "n_out", "radius",
                     "out_dtype", "interpret"))
def decode_tiles_fused(rows, start_local, end_local, off_local, lut_base,
                       dec_sym, dec_len, opos, oval, two_eb, max_len: int,
                       tile_syms: int, ss_max: int, n_out: int, radius: int,
                       out_dtype=jnp.float32, interpret: bool = True):
    """Tile-centric decode+write with the fused dequant/reconstruct epilogue.

    First seven inputs are exactly ``huffman_decode.decode_tiles``; the
    epilogue inputs are ``opos``/``oval`` (the -1-padded outlier side list,
    int32[m_pad]) and ``two_eb`` (float32[1], the reconstruction scale).
    Output positions past ``n_out`` in the final tile decode as zero codes
    and would corrupt the carry, but no tile follows, so the sliced result
    is exact.  Returns ``out_dtype[n_out]`` (float32 default; bf16/f16
    outputs are computed in f32 and cast once).
    """
    n_tiles = rows.shape[0]
    lut = dec_sym.shape[0]
    m = opos.shape[0]
    kernel = functools.partial(decode_tiles_fused_kernel_body,
                               max_len=max_len, tile_syms=tile_syms,
                               radius=radius, out_dtype=out_dtype)
    tiles = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, ss_max, C.ROW_UNITS), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((m,), lambda t: (0,)),
            pl.BlockSpec((m,), lambda t: (0,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile_syms), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_syms), out_dtype),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(rows, start_local, end_local, off_local, lut_base, dec_sym, dec_len,
      opos, oval, two_eb)
    return tiles.reshape(-1)[:n_out]


def decode_tiles_fused_nd_kernel_body(rows_ref, start_ref, end_ref, off_ref,
                                      lut_ref, sym_ref, len_ref, opos_ref,
                                      oval_ref, teb_ref, out_ref, row_carry,
                                      plane_carry, *, max_len, rows_per_tile,
                                      plane_rows, cols, planes, radius,
                                      out_dtype):
    t = pl.program_id(0)
    block = rows_per_tile * cols
    tile = C.stage_tile(rows_ref[0], start_ref[0], end_ref[0], off_ref[0],
                        lut_ref[0], sym_ref[...], len_ref[...], max_len,
                        block)
    d = _dequant_block(tile, t * block, opos_ref[...], oval_ref[...],
                       radius=radius, block=block)
    out_ref[0] = _recon_rows_block(d, t, row_carry, plane_carry, teb_ref[0],
                                   rows_per_tile=rows_per_tile,
                                   plane_rows=plane_rows, cols=cols,
                                   planes=planes, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("max_len", "rows_per_tile", "shape", "ss_max", "radius",
                     "out_dtype", "interpret"))
def decode_tiles_fused_nd(rows, start_local, end_local, off_local, lut_base,
                          dec_sym, dec_len, opos, oval, two_eb, max_len: int,
                          rows_per_tile: int, shape: tuple, ss_max: int,
                          radius: int, out_dtype=jnp.float32,
                          interpret: bool = True):
    """:func:`decode_tiles_fused` with the 2-D/3-D inverse-Lorenzo epilogue.

    ``shape`` is the squeezed logical shape, ``(R, C)`` or ``(P, R, C)``;
    each grid step decodes ``rows_per_tile`` whole rows of ``C`` symbols
    (``rows_per_tile`` must divide ``R`` for 3-D so tiles never cross a
    plane boundary) and reconstructs them against the VMEM row/plane
    carries.  Returns ``out_dtype[prod(shape)]`` (flat, C-order).
    """
    assert len(shape) in (2, 3), shape
    planes = shape[0] if len(shape) == 3 else 1
    plane_rows, cols = shape[-2], shape[-1]
    if planes > 1:
        assert plane_rows % rows_per_tile == 0, (shape, rows_per_tile)
    n_out = 1
    for s in shape:
        n_out *= s
    block = rows_per_tile * cols
    n_tiles = rows.shape[0]
    lut = dec_sym.shape[0]
    m = opos.shape[0]
    kernel = functools.partial(
        decode_tiles_fused_nd_kernel_body, max_len=max_len,
        rows_per_tile=rows_per_tile, plane_rows=plane_rows, cols=cols,
        planes=planes, radius=radius, out_dtype=out_dtype)
    # The plane carry is only live for 3-D; 2-D allocates a 1x1 stub so the
    # kernel arity is static.
    plane_scratch = pltpu.VMEM(
        (plane_rows, cols) if planes > 1 else (1, 1), jnp.int32)
    tiles = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, ss_max, C.ROW_UNITS), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((1, ss_max), lambda t: (t, 0)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((lut,), lambda t: (0,)),
            pl.BlockSpec((m,), lambda t: (0,)),
            pl.BlockSpec((m,), lambda t: (0,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, block), out_dtype),
        scratch_shapes=[pltpu.VMEM((cols,), jnp.int32), plane_scratch],
        interpret=interpret,
    )(rows, start_local, end_local, off_local, lut_base, dec_sym, dec_len,
      opos, oval, two_eb)
    return tiles.reshape(-1)[:n_out]


def dequant_recon_kernel_body(codes_ref, opos_ref, oval_ref, teb_ref,
                              out_ref, carry, *, radius, block,
                              out_dtype=jnp.float32):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry[0] = jnp.int32(0)

    base = pl.program_id(0) * block
    out_ref[...] = _dequant_recon_block(codes_ref[...], base, opos_ref[...],
                                        oval_ref[...], carry, teb_ref[0],
                                        radius=radius, block=block,
                                        out_dtype=out_dtype)


@functools.partial(
    jax.jit, static_argnames=("radius", "block", "out_dtype", "interpret"))
def dequant_reconstruct(codes, opos, oval, two_eb, radius: int,
                        block: int = 4096, out_dtype=jnp.float32,
                        interpret: bool = True):
    """Standalone fused epilogue: uint16 codes -> reconstructed floats.

    ``lorenzo.reconstruct1d`` extended with dequantization (``- radius``)
    and the outlier scatter; chained after the padded baseline decoder.
    ``codes`` must be padded to a ``block`` multiple (pad codes decode past
    the real output and only pollute the final block's tail).
    """
    n = codes.shape[0]
    assert n % block == 0, (n, block)
    m = opos.shape[0]
    kernel = functools.partial(dequant_recon_kernel_body, radius=radius,
                               block=block, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), out_dtype),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(codes, opos, oval, two_eb)


def dequant_recon_nd_kernel_body(codes_ref, opos_ref, oval_ref, teb_ref,
                                 out_ref, row_carry, plane_carry, *, radius,
                                 rows_per_tile, plane_rows, cols, planes,
                                 out_dtype):
    t = pl.program_id(0)
    block = rows_per_tile * cols
    d = _dequant_block(codes_ref[...], t * block, opos_ref[...],
                       oval_ref[...], radius=radius, block=block)
    out_ref[...] = _recon_rows_block(d, t, row_carry, plane_carry,
                                     teb_ref[0], rows_per_tile=rows_per_tile,
                                     plane_rows=plane_rows, cols=cols,
                                     planes=planes, out_dtype=out_dtype)


@functools.partial(
    jax.jit, static_argnames=("radius", "shape", "rows_per_tile",
                              "out_dtype", "interpret"))
def dequant_reconstruct_nd(codes, opos, oval, two_eb, radius: int,
                           shape: tuple, rows_per_tile: int,
                           out_dtype=jnp.float32, interpret: bool = True):
    """:func:`dequant_reconstruct` with the 2-D/3-D epilogue.

    Same row/plane-carry scheme as :func:`decode_tiles_fused_nd`; ``codes``
    must be padded to a whole number of ``rows_per_tile * shape[-1]``
    tiles (pad rows sit strictly after the valid output).  Returns
    ``out_dtype[prod(shape)]`` (flat, C-order).
    """
    assert len(shape) in (2, 3), shape
    planes = shape[0] if len(shape) == 3 else 1
    plane_rows, cols = shape[-2], shape[-1]
    if planes > 1:
        assert plane_rows % rows_per_tile == 0, (shape, rows_per_tile)
    block = rows_per_tile * cols
    n = codes.shape[0]
    assert n % block == 0, (n, block)
    n_out = 1
    for s in shape:
        n_out *= s
    m = opos.shape[0]
    kernel = functools.partial(
        dequant_recon_nd_kernel_body, radius=radius,
        rows_per_tile=rows_per_tile, plane_rows=plane_rows, cols=cols,
        planes=planes, out_dtype=out_dtype)
    plane_scratch = pltpu.VMEM(
        (plane_rows, cols) if planes > 1 else (1, 1), jnp.int32)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), out_dtype),
        scratch_shapes=[pltpu.VMEM((cols,), jnp.int32), plane_scratch],
        interpret=interpret,
    )(codes, opos, oval, two_eb)
    return out[:n_out]
