"""Pallas kernel for the self-synchronization phase (W&S, paper §IV-A).

Grid over sequences; lanes are the sequence's subsequences.  Each round every
lane decodes its 128-bit window from its current candidate offset and hands
the landing position to the next lane; the block reaches a fixed point when
no offset changes.

The paper's optimization -- exiting the block as soon as *all* lanes have
validated their sync point (`__all_sync`) instead of spinning to the
worst-case bound -- maps to the ``while_loop``-with-convergence-predicate
here; the un-optimized variant (``early_exit=False``) runs the worst-case
``subseqs_per_seq`` rounds unconditionally.  Both are kept so the benchmark
can reproduce the paper's ~11% phase-1 win.

Inter-sequence synchronization (phase 2) chains sequence-head offsets at the
ops level (`repro.kernels.ops.selfsync_sync`) -- a separate launch, as in the
paper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common as C


def selfsync_kernel_body(rows_ref, head_ref, end_ref, sym_ref, len_ref,
                         start_ref, counts_ref, land_ref, rounds_ref, *,
                         max_len, early_exit, subseqs_per_seq):
    rows = rows_ref[0]            # (SS, ROW_UNITS)
    head = head_ref[0]            # (1,) int32: candidate offset of lane 0
    end = end_ref[0]              # (SS,) row-local window ends
    dec_sym = sym_ref[...]
    dec_len = len_ref[...]
    ss = rows.shape[0]

    start0 = jnp.zeros((ss,), jnp.int32).at[0].set(head[0])

    def round_fn(start):
        landing, counts = C.decode_window(rows, start, end, dec_sym, dec_len,
                                          max_len, collect=False)
        # landing is local to each lane's row; lane j's landing lies in
        # [128, 128+max_len) => offset (landing - 128) into lane j+1's row.
        prop = jnp.concatenate([start[:1], landing[:-1] - 128])
        return prop, landing, counts

    if early_exit:
        def cond(state):
            start, _, _, changed, rounds = state
            return jnp.logical_and(changed, rounds < subseqs_per_seq)

        def body(state):
            start, _, _, _, rounds = state
            new_start, landing, counts = round_fn(start)
            changed = jnp.any(new_start != start)
            return new_start, landing, counts, changed, rounds + 1

        zero = jnp.zeros((ss,), jnp.int32)
        start, landing, counts, _, rounds = jax.lax.while_loop(
            cond, body, (start0, zero, zero, jnp.bool_(True), jnp.int32(0)))
    else:
        start, landing, counts = start0, None, None
        for _ in range(subseqs_per_seq):
            start, landing, counts = round_fn(start)
        rounds = jnp.int32(subseqs_per_seq)

    start_ref[0] = start
    counts_ref[0] = counts
    land_ref[0] = landing
    rounds_ref[0] = rounds[None]


@functools.partial(
    jax.jit,
    static_argnames=("max_len", "subseqs_per_seq", "early_exit", "interpret"))
def selfsync_intra(rows, heads, end_local, dec_sym, dec_len, max_len: int,
                   subseqs_per_seq: int, early_exit: bool = True,
                   interpret: bool = True):
    """Per-sequence sync discovery.

    rows: uint32[n_seq, SS, ROW_UNITS]; heads: int32[n_seq, 1] candidate
    offsets for each sequence's first subsequence; end_local: int32[n_seq, SS].
    Returns (start_local, counts, landing, rounds) with shapes
    ([n_seq, SS], [n_seq, SS], [n_seq, SS], [n_seq, 1]).
    """
    n_seq, ss, _ = rows.shape
    lut = dec_sym.shape[0]
    kernel = functools.partial(
        selfsync_kernel_body, max_len=max_len, early_exit=early_exit,
        subseqs_per_seq=subseqs_per_seq)
    return pl.pallas_call(
        kernel,
        grid=(n_seq,),
        in_specs=[
            pl.BlockSpec((1, ss, C.ROW_UNITS), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
            pl.BlockSpec((1, ss), lambda s: (s, 0)),
            pl.BlockSpec((lut,), lambda s: (0,)),
            pl.BlockSpec((lut,), lambda s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, ss), lambda s: (s, 0)),
            pl.BlockSpec((1, ss), lambda s: (s, 0)),
            pl.BlockSpec((1, ss), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_seq, ss), jnp.int32),
            jax.ShapeDtypeStruct((n_seq, ss), jnp.int32),
            jax.ShapeDtypeStruct((n_seq, ss), jnp.int32),
            jax.ShapeDtypeStruct((n_seq, 1), jnp.int32),
        ],
        interpret=interpret,
    )(rows, heads, end_local, dec_sym, dec_len)
