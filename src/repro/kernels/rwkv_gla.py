"""Pallas chunked-GLA kernel for RWKV-6 time-mix (§Perf hillclimb A).

The XLA formulation of the per-channel-decay recurrence moves the
(B, H, dk, dv) state through HBM on *every token* (the dominant term of
rwkv6-3b train_4k: t_memory 495 s vs t_compute 0.5 s -- 0.1% of roofline).
This kernel keeps the state in VMEM scratch across a whole sequence: grid =
(BH blocks, sequence chunks sequential); per chunk it loads (r,k,v,w) tiles,
runs the exact per-step recurrence on VMEM-resident state, and writes only
the y tile -- HBM traffic collapses to inputs + outputs:

  before: ~2 * S * B*H*dk*dv * 4 B  (state RW per token)
  after:   5 * S * B*H*dk   * bytes (r,k,v,w in + y out)  => dk/2x less

Layout: lanes carry dv (=64, padded to 128 on TPU), sublanes dk; one (B,H)
pair per grid row keeps BlockSpecs rectangular.  Validated in interpret
mode against repro.models.rwkv.time_mix (tests/test_rwkv_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state,
                *, chunk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0]          # (chunk, dk)
    k = k_ref[0]
    v = v_ref[0]          # (chunk, dv)
    w = w_ref[0]          # (chunk, dk)
    u = u_ref[0]          # (1, dk) bonus

    def step(t, s):
        kv = k[t][:, None] * v[t][None, :]            # (dk, dv)
        y = (r[t][:, None] * (s + u[:, None] * kv)).sum(axis=0)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return w[t][:, None] * s + kv

    state[...] = jax.lax.fori_loop(0, chunk, step, state[...])


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def gla_time_mix(r, k, v, w, u, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: (BH, S, dk|dv) fp32; u: (BH, dk).  Returns y (BH, S, dv)
    plus the final state (BH, dk, dv)."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    assert s % chunk == 0
    n_chunks = s // chunk

    y = pl.pallas_call(
        functools.partial(_gla_kernel, chunk=chunk),
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y


def hbm_bytes_xla(b, h, s, dk, dv, layers, passes=3):
    """State HBM traffic of the XLA per-step scan (before)."""
    return 2 * s * b * h * dk * dv * 4 * layers * passes


def hbm_bytes_kernel(b, h, s, dk, dv, layers, passes=3):
    """Input+output traffic of the kernel (after)."""
    return (3 * s * b * h * dk + 2 * s * b * h * dv) * 4 * layers * passes
