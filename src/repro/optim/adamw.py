"""Functional AdamW with optional int8 block-quantized state.

The int8 path stores m and v as int8 with per-block (128) fp32 scales --
~4.25 bytes/param of optimizer state instead of 8.  This is what lets
deepseek-v3-671b fit the 256-chip single-pod mesh (DESIGN.md §6), and it is
philosophically the paper's trick applied to optimizer state: bounded-error
quantization of a tensor whose consumer tolerates noise.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # "float32" | "int8"


def _quantizable(shape) -> bool:
    if len(shape) == 0:
        return False
    return shape[-1] % BLOCK == 0


def quantize_state(x):
    """int8 state, blocked along the LAST dim, kept in the param's shape.

    Shape preservation is load-bearing: a flat-blocked layout shards
    differently from the param, and the reshape between the two made GSPMD
    all-gather the dequantized f32 state (406 GiB per MoE stack on
    deepseek-v3).  Non-conforming leaves (tiny / last dim not a multiple of
    128) stay f32 under the "f" key.
    """
    if not _quantizable(x.shape):
        return {"f": x.astype(jnp.float32)}
    nb = x.shape[-1] // BLOCK
    blocks = x.reshape(*x.shape[:-1], nb, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0          # (..., nb)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127,
                 127).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale.astype(jnp.float32)}


def dequantize_state(s, shape):
    if "f" in s:
        return s["f"]
    nb = shape[-1] // BLOCK
    q = s["q"].reshape(*shape[:-1], nb, BLOCK)
    return (q.astype(jnp.float32) * s["scale"][..., None]).reshape(shape)


def init(params, cfg: AdamWConfig):
    def zeros_like_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_dtype == "int8":
            return quantize_state(z)
        return z

    return {
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


@partial(jax.jit, static_argnames=("cfg",))
def update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.state_dtype == "int8":
            m_f, v_f = dequantize_state(m, p.shape), dequantize_state(v, p.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_f / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_f / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        if cfg.state_dtype == "int8":
            return new_p, quantize_state(m_f), quantize_state(v_f)
        return new_p, m_f, v_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    if cfg.state_dtype == "int8":
        # m/v leaves are dicts; flatten at the same granularity as params.
        flat_m = tdef.flatten_up_to(opt_state["m"])
        flat_v = tdef.flatten_up_to(opt_state["v"])
    else:
        flat_m = jax.tree.leaves(opt_state["m"])
        flat_v = jax.tree.leaves(opt_state["v"])

    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm}
