"""Mesh-sharded archive layout: manifest + ``ShardedWriter``.

A sharded archive is a directory:

    <dir>/shard_manifest.json     entry -> tile records (see below)
    <dir>/shard_00000.szt         ordinary ``.szt`` archives, one per
    <dir>/shard_00001.szt         "host" (shard), each fully
    ...                           self-describing and CRC-checked

Each tensor is partitioned by its ``runtime/sharding.py`` partition spec
into a grid of tiles (``partition.spec_parts``); every tile compresses
independently through the codec and lands as one chunk in one shard
archive, written by a plain ``store.ArchiveWriter``.  Tiles are assigned
to shards in contiguous linear-index blocks -- the row-major device order
of a mesh maps hosts to contiguous device ranges, so a host's shard holds
exactly the tiles its devices own.  Fully-replicated (single-tile)
entries rotate across shards to balance bytes.

The manifest records, per entry, the global shape/dtype, the partition
grid, and per tile the owning shard, chunk name, global offset, tile
shape, and payload CRC.  Nothing in the layout depends on the writing
topology beyond those offsets: a checkpoint written at H hosts restores
at any H' (``restore.ShardedRestorer`` reshards on read).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.distributed import partition as pt
from repro.store import format as F
from repro.store.writer import ArchiveWriter


class ShardManifestError(F.StoreError):
    """The sharded-archive manifest is missing, torn, or invalid."""


def chunk_name(entry: str, index: tuple) -> str:
    """Chunk name of one tile inside its shard archive."""
    return f"{entry}@{'.'.join(map(str, index))}" if index else entry


def write_manifest(path: str, doc: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_manifest(directory: str) -> dict:
    """Parse and validate a sharded-archive manifest; every failure mode
    is the named ``ShardManifestError``."""
    path = os.path.join(directory, F.SHARD_MANIFEST_NAME)
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError as e:
        raise ShardManifestError(
            f"{directory}: {F.SHARD_MANIFEST_NAME} is missing") from e
    except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
        raise ShardManifestError(
            f"{directory}: {F.SHARD_MANIFEST_NAME} is torn or unreadable: "
            f"{e}") from e
    version = doc.get("version") if isinstance(doc, dict) else None
    if not isinstance(version, int):
        raise ShardManifestError(
            f"{directory}: {F.SHARD_MANIFEST_NAME} is structurally invalid")
    if version > F.SHARD_MANIFEST_VERSION:
        raise ShardManifestError(
            f"{directory}: shard manifest version {version} is newer than "
            f"this reader (supports <= {F.SHARD_MANIFEST_VERSION})")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        raise ShardManifestError(
            f"{directory}: {F.SHARD_MANIFEST_NAME} has no entries table")
    for name, meta in entries.items():
        if not (isinstance(meta, dict) and isinstance(meta.get("tiles"), list)
                and meta.get("shape") is not None and meta.get("dtype")):
            raise ShardManifestError(
                f"{directory}: manifest entry {name!r} is invalid")
        for t in meta["tiles"]:
            if not (isinstance(t, dict) and "shard" in t and "chunk" in t
                    and "offset" in t and "shape" in t):
                raise ShardManifestError(
                    f"{directory}: tile record of entry {name!r} is invalid")
    return doc


class ShardedWriter:
    """Write one mesh-sharded archive directory.

    ``mesh`` supplies the partition-axis sizes -- a ``jax.sharding.Mesh``
    or a plain ``{axis: size}`` mapping (layouts can be written without
    any devices; only *restore into shardings* needs them).  ``n_shards``
    is the number of per-host archives (default 1: a single-process
    writer is one "host"); it is write-time layout only and places no
    constraint on the restore topology.
    """

    def __init__(self, directory: str, mesh=None, *, codec=None,
                 n_shards: int = 1):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if codec is None:
            from repro.core.codec import default_codec
            codec = default_codec()
        self.dir = directory
        self.codec = codec
        self.axis_sizes = pt.axis_sizes_of(mesh) if mesh is not None else {}
        self.n_shards = n_shards
        os.makedirs(directory, exist_ok=True)
        self._writers: dict[int, ArchiveWriter] = {}
        self._entries: dict[str, dict] = {}
        self._rr = 0                 # rotation cursor for single-tile entries
        self._closed = False

    def _writer(self, shard: int) -> ArchiveWriter:
        w = self._writers.get(shard)
        if w is None:
            w = ArchiveWriter(os.path.join(self.dir, F.shard_filename(shard)),
                              codec=self.codec)
            self._writers[shard] = w
        return w

    def add(self, name: str, array, spec=None, *,
            orig_dtype: "str | None" = None):
        """Partition ``array`` by ``spec`` and append its tiles.

        ``spec`` is a ``PartitionSpec`` resolved against the writer's mesh
        axes, or a ``NamedSharding`` (whose own mesh supplies the axis
        sizes), or ``None`` for a replicated single-tile entry.
        """
        if self._closed:
            raise F.StoreError("sharded writer already closed")
        if name in self._entries:
            raise F.StoreError(f"duplicate entry name {name!r}")
        arr = np.asarray(array)
        axis_sizes = self.axis_sizes
        if spec is not None and hasattr(spec, "spec"):   # NamedSharding
            axis_sizes = pt.axis_sizes_of(spec.mesh)
            spec = spec.spec
        parts = pt.spec_parts(spec, arr.shape, axis_sizes)
        tiles = list(pt.tile_extents(arr.shape, parts))
        n_tiles = len(tiles)
        records = []
        for lin, (index, offset, tshape) in enumerate(tiles):
            if n_tiles == 1:
                shard = self._rr % self.n_shards
                self._rr += 1
            else:
                shard = lin * self.n_shards // n_tiles
            cname = chunk_name(name, index)
            tile = np.ascontiguousarray(arr[pt.tile_slice(offset, tshape)])
            w = self._writer(shard)
            w.add(cname, self.codec.compress(tile),
                  orig_dtype=orig_dtype or str(arr.dtype))
            records.append({"shard": shard, "chunk": cname,
                            "offset": list(offset), "shape": list(tshape),
                            "crc32": w.checksums()[cname]})
        self._entries[name] = {
            "shape": [int(s) for s in arr.shape],
            "dtype": str(orig_dtype or arr.dtype),
            "parts": list(parts), "tiles": records}

    def manifest(self) -> dict:
        return {"version": F.SHARD_MANIFEST_VERSION,
                "n_shards": self.n_shards,
                "axis_sizes": dict(self.axis_sizes),
                "entries": self._entries}

    def close(self):
        if self._closed:
            return
        self._closed = True
        for w in self._writers.values():
            w.close()
        write_manifest(os.path.join(self.dir, F.SHARD_MANIFEST_NAME),
                       self.manifest())

    def abort(self):
        if not self._closed:
            self._closed = True
            for w in self._writers.values():
                w.abort()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
        else:
            self.abort()
        return False
