"""``ShardedRestorer``: decode a mesh-sharded archive into target shardings.

Restore runs each shard's stage->decode pipeline concurrently (one worker
per shard, each an ordinary ``store.Archive`` whose ``iter_decode`` already
double-buffers disk reads against class-merged decode), then lands every
entry *directly* in its target ``NamedSharding``: each target device's
slice is assembled host-side from the decoded tiles that overlap it and
placed with ``jax.device_put``, and the global array is constructed with
``jax.make_array_from_single_device_arrays`` -- the unsharded tensor is
never materialized when a sharding is given.  When the restore topology
matches the write grid, every device slice is exactly one tile and the
assembly is copy-free.

All shard archives share the restorer's codec, so its digest-keyed plan
cache deduplicates phase 1-3 plans across shards (identical tiles -- e.g.
zero-initialized layers -- build one plan total), and a re-restore builds
zero plans.

Failure containment follows docs/robustness.md: a corrupt or missing
shard quarantines only the entries with tiles in that shard -- the reason
names the shard file -- and every other shard restores.  ``policy``
selects ``"raise"`` / ``"skip"`` / ``"zero_fill"`` semantics per entry.
"""

from __future__ import annotations

import concurrent.futures as futures
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import partition as pt
from repro.distributed.shards import load_manifest
from repro.store import format as F
from repro.store.reader import Archive


def _np_dtype(name):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, str(name)))


class ShardedRestorer:
    """One open mesh-sharded archive directory (see ``shards.py``)."""

    def __init__(self, directory: str, *, codec=None):
        if codec is None:
            from repro.core.codec import default_codec
            codec = default_codec()
        self.dir = directory
        self.codec = codec
        self.manifest = load_manifest(directory)
        self.entries: dict = self.manifest["entries"]
        self.stats = {"shards_opened": 0, "tiles_decoded": 0,
                      "entries_quarantined": 0, "io_retries": 0}

    @property
    def names(self) -> list:
        return list(self.entries)

    def entry_shape(self, name: str) -> tuple:
        return tuple(int(s) for s in self.entries[name]["shape"])

    # -- per-shard decode ----------------------------------------------------

    def _read_shard(self, shard: int, chunks: list, validate: bool):
        """Decode one shard's tile chunks; returns (decoded, failed) maps.

        Failures are collected, never raised, so one bad shard cannot
        abort its siblings mid-flight; the entry loop applies the policy.
        """
        path = os.path.join(self.dir, F.shard_filename(shard))
        fname = F.shard_filename(shard)
        if not os.path.exists(path):
            err = F.StoreCorruptError(
                f"shard {fname} is missing from {self.dir}")
            return {}, {c: err for c in chunks}
        failed: dict = {}

        def on_error(name, exc):
            failed[name] = F.StoreCorruptError(f"shard {fname}: {exc}")

        try:
            with Archive(path, codec=self.codec) as ar:
                decoded = ar.read_all(chunks, policy="skip",
                                      on_error=on_error, validate=validate,
                                      as_numpy=True)
                self.stats["io_retries"] += ar.stats["io_retries"]
        except F.StoreError as e:
            err = F.StoreCorruptError(
                f"shard {fname} is corrupt or truncated: {e}")
            err.__cause__ = e
            return {}, {c: err for c in chunks}
        self.stats["shards_opened"] += 1
        self.stats["tiles_decoded"] += len(decoded)
        return decoded, failed

    # -- assembly ------------------------------------------------------------

    def _place(self, name: str, meta: dict, tiles: dict, sharding):
        """Assemble one entry from its decoded tiles.

        With a sharding: per-device slices only, glued into a global array
        via ``make_array_from_single_device_arrays`` (asserted to land in
        the target sharding -- there is no gather-then-reshard hop to get
        wrong).  Without: the full host array.
        """
        shape = self.entry_shape(name)
        dtype = _np_dtype(meta["dtype"])
        if sharding is None:
            full_idx = tuple(slice(0, n) for n in shape)
            return jnp.asarray(pt.extract_slice(full_idx, tiles, dtype,
                                                shape))
        dmap = sharding.addressable_devices_indices_map(shape)
        locals_ = []
        for d, idx in dmap.items():
            sl = tuple(idx)
            if len(sl) < len(shape):            # jax may elide trailing dims
                sl += (slice(None),) * (len(shape) - len(sl))
            locals_.append(jax.device_put(
                pt.extract_slice(sl, tiles, dtype, shape), d))
        out = jax.make_array_from_single_device_arrays(shape, sharding,
                                                       locals_)
        assert out.sharding.is_equivalent_to(sharding, len(shape)), \
            f"entry {name!r} did not land in its target sharding"
        return out

    def _substitute(self, name: str, meta: dict, pol, sharding):
        """Zeros in the target sharding for a quarantined entry, or None."""
        if pol.on_error != "zero_fill":
            return None
        shape = self.entry_shape(name)
        zeros = jnp.zeros(shape, jnp.dtype(meta["dtype"]))
        return zeros if sharding is None else jax.device_put(zeros, sharding)

    # -- public --------------------------------------------------------------

    def decode_shards(self, shards, *, devices=None,
                      validate: bool = True) -> dict:
        """Decode the tile chunks of ``shards`` -- one host's local share.

        This is the per-host critical path of a multi-host restore: each
        host decodes only the shard archives its devices own and places
        the tiles locally (``devices`` round-robins them with
        ``jax.device_put``); gluing the per-device pieces into global
        arrays is metadata-only (``make_array_from_single_device_arrays``
        across processes).  Returns ``{chunk: array}``; any shard failure
        raises (salvage semantics live in :meth:`restore`).
        """
        by_shard: dict[int, list] = {s: [] for s in shards}
        for meta in self.entries.values():
            for t in meta["tiles"]:
                s = int(t["shard"])
                if s in by_shard:
                    by_shard[s].append(t["chunk"])
        out: dict = {}
        for s, chunks in sorted(by_shard.items()):
            decoded, failed = self._read_shard(s, chunks, validate)
            if failed:
                raise next(iter(failed.values()))
            out.update(decoded)
        if devices is not None:
            devices = list(devices)
            out = {c: jax.device_put(a, devices[i % len(devices)])
                   for i, (c, a) in enumerate(out.items())}
            for a in out.values():
                a.block_until_ready()
        return out

    def restore(self, shardings: "dict | None" = None, *, names=None,
                policy=None, on_error=None, validate: bool = True,
                concurrency: "int | None" = None) -> dict:
        """Restore entries into ``{name: array}``.

        ``shardings`` maps entry name -> target ``NamedSharding`` (missing
        or ``None`` values restore as full host-assembled arrays).  Shards
        decode concurrently (``concurrency`` workers, default one per
        shard); ``policy`` / ``on_error`` follow the store's recovery
        semantics, with quarantine reasons naming the failing shard file.
        """
        shardings = shardings or {}
        pol = self.codec.recovery_policy(policy)
        names = self.names if names is None else list(names)
        unknown = [n for n in names if n not in self.entries]
        if unknown:
            raise KeyError(f"{self.dir}: no entries named {unknown}")

        by_shard: dict[int, list] = {}
        chunk_entry: dict[str, str] = {}
        for name in names:
            for t in self.entries[name]["tiles"]:
                by_shard.setdefault(int(t["shard"]), []).append(t["chunk"])
                chunk_entry[t["chunk"]] = name

        decoded: dict = {}
        failed: dict = {}
        workers = min(len(by_shard), concurrency or len(by_shard)) or 1
        if workers <= 1 or len(by_shard) <= 1:
            results = [self._read_shard(s, cs, validate)
                       for s, cs in sorted(by_shard.items())]
        else:
            with futures.ThreadPoolExecutor(
                    workers, thread_name_prefix="szt-shard") as pool:
                results = list(pool.map(
                    lambda sc: self._read_shard(sc[0], sc[1], validate),
                    sorted(by_shard.items())))
        for dec, fail in results:
            decoded.update(dec)
            failed.update(fail)

        out: dict = {}
        for name in names:
            meta = self.entries[name]
            sharding = shardings.get(name)
            bad = [t for t in meta["tiles"] if t["chunk"] in failed]
            if bad:
                exc = failed[bad[0]["chunk"]]
                if pol.on_error == "raise":
                    raise exc
                self.stats["entries_quarantined"] += 1
                if on_error is not None:
                    on_error(name, exc)
                sub = self._substitute(name, meta, pol, sharding)
                if sub is not None:
                    out[name] = sub
                continue
            tiles = {
                (tuple(int(o) for o in t["offset"]),
                 tuple(int(s) for s in t["shape"])): decoded[t["chunk"]]
                for t in meta["tiles"]}
            out[name] = self._place(name, meta, tiles, sharding)
        return out
