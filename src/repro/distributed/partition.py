"""Spec-driven tensor partitioning for the sharded archive layout.

A tensor's ``PartitionSpec`` (from ``runtime/sharding.py``) determines how
the sharded writer tiles it: each dimension splits into as many parts as
the product of its mesh axes, with the same divisibility fallback as
``runtime.sharding._fit`` -- a dim that does not divide evenly stays whole
(replication), never padded.  Tiles carry their global offset and shape in
the manifest, so the partition grid is pure metadata: any restore topology
can reassemble any slice from the tile records, which is what makes the
layout host-count-agnostic.
"""

from __future__ import annotations

import itertools

import numpy as np


def axis_sizes_of(mesh) -> dict:
    """``{axis name: size}`` from a ``jax.sharding.Mesh`` or a plain
    mapping (the latter lets layout code and tests run without devices)."""
    shape = getattr(mesh, "shape", mesh)
    return {str(k): int(v) for k, v in dict(shape).items()}


def _axes_product(axis_sizes: dict, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a not in axis_sizes:
            raise ValueError(f"partition axis {a!r} not in mesh axes "
                             f"{sorted(axis_sizes)}")
        n *= axis_sizes[a]
    return n


def spec_parts(spec, shape: tuple, axis_sizes: dict) -> tuple:
    """Parts per dimension for ``spec`` over a mesh of ``axis_sizes``.

    Mirrors ``runtime.sharding._fit``: an indivisible dim degrades to one
    part (replication) instead of erroring, so any spec the sharding rules
    emit produces a valid grid.  ``spec=None`` means fully replicated.
    """
    entries = tuple(spec) if spec is not None else ()
    parts = []
    for i, dim in enumerate(shape):
        ax = entries[i] if i < len(entries) else None
        n = _axes_product(axis_sizes, ax)
        parts.append(n if n > 1 and dim % n == 0 else 1)
    return tuple(parts)


def tile_extents(shape: tuple, parts: tuple):
    """Yield ``(index, offset, tile_shape)`` for every tile of the grid,
    in row-major index order (the linear order shard assignment uses)."""
    if len(parts) != len(shape):
        raise ValueError(f"parts {parts} does not match shape {shape}")
    steps = tuple(dim // p for dim, p in zip(shape, parts))
    for index in itertools.product(*(range(p) for p in parts)):
        offset = tuple(i * s for i, s in zip(index, steps))
        yield index, offset, steps


def tile_slice(offset: tuple, tile_shape: tuple) -> tuple:
    """The global-array slice covered by one tile."""
    return tuple(slice(o, o + s) for o, s in zip(offset, tile_shape))


def extract_slice(index, tiles: dict, dtype, out_shape: tuple):
    """Assemble the sub-array covered by ``index`` (a tuple of slices into
    the global array) from decoded tiles.

    ``tiles`` maps ``(offset, tile_shape)`` -> decoded ``np.ndarray``.
    When the requested slice is exactly one tile, that tile is returned
    without a copy -- the matched-topology fast path, where every device's
    shard is one tile of the write grid.
    """
    bounds = tuple((s.start or 0, s.stop if s.stop is not None else n)
                   for s, n in zip(index, out_shape))
    for (offset, tshape), arr in tiles.items():
        if all(b == o and e == o + t
               for (b, e), o, t in zip(bounds, offset, tshape)):
            return arr
    local = np.empty(tuple(e - b for b, e in bounds), dtype)
    filled = 0
    for (offset, tshape), arr in tiles.items():
        dst, src = [], []
        empty = False
        for (b, e), o, t in zip(bounds, offset, tshape):
            lo, hi = max(b, o), min(e, o + t)
            if lo >= hi:
                empty = True
                break
            dst.append(slice(lo - b, hi - b))
            src.append(slice(lo - o, hi - o))
        if empty:
            continue
        local[tuple(dst)] = arr[tuple(src)]
        filled += int(np.prod([s.stop - s.start for s in dst]))
    if filled != local.size:
        raise ValueError(
            f"tiles cover {filled} of {local.size} elements of slice "
            f"{bounds} -- tile records are inconsistent with the shape")
    return local
