"""Distributed restore: mesh-sharded archives, per-device decode.

See ``docs/distributed.md``.  ``ShardedWriter`` partitions tensors by
their partition specs into per-host ``.szt`` shard archives plus a JSON
manifest; ``ShardedRestorer`` decodes the shards concurrently and lands
every entry directly in a target ``NamedSharding`` -- the layout is
host-count-agnostic, so any write topology restores at any read topology.
"""

from repro.distributed.partition import (axis_sizes_of, extract_slice,
                                         spec_parts, tile_extents,
                                         tile_slice)
from repro.distributed.restore import ShardedRestorer
from repro.distributed.shards import (ShardedWriter, ShardManifestError,
                                      chunk_name, load_manifest)

__all__ = [
    "ShardedWriter", "ShardedRestorer", "ShardManifestError",
    "axis_sizes_of", "spec_parts", "tile_extents", "tile_slice",
    "extract_slice", "chunk_name", "load_manifest",
]
