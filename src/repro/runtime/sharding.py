"""Parameter / activation sharding rules (GSPMD, ZeRO-3 + TP + EP).

Rules are keyed on (path substring, trailing-ndim) of each parameter leaf;
stacked-layer leading dims are never sharded.  Every axis assignment is
divisibility-checked against the mesh: a dim too small for its axis falls
back to replication (e.g. kv_heads=2 on a 16-way model axis), a dim >= the
axis size but not divisible is left to GSPMD padding (e.g. 60 experts).

Scheme (DESIGN.md §6):
  * "model": heads / d_ff / experts / vocab  (TP + EP + vocab-parallel)
  * ("pod","data"): the other large dim of every matrix  (ZeRO-3 / FSDP --
    XLA inserts per-layer all-gathers under the layer scan and overlaps them
    with compute)
  * activations: batch on ("pod","data")
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes, model_axis


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh, dim_size: int, axes):
    """Return ``axes`` if the dim divides evenly over them, else None.

    jit argument shardings require exact divisibility (GSPMD padding is
    only available to *internal* values), so anything that does not divide
    falls back to replication and the rule set must route the sharding to a
    dim that does (e.g. expert-TP instead of EP for 60 experts)."""
    n = _axis_size(mesh, axes)
    if n <= 1:
        return None
    if dim_size % n == 0:
        return axes
    return None


def param_spec(path: str, shape: tuple, mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    fsdp = batch_axes(mesh)
    tp = model_axis(mesh)
    nd = len(shape)

    def spec(*trailing):
        """Pad with None for stacked leading dims, divisibility-check."""
        lead = nd - len(trailing)
        checked = tuple(_fit(mesh, shape[lead + i], ax)
                        for i, ax in enumerate(trailing))
        return P(*((None,) * lead + checked))

    # --- order matters: more specific substrings first ---
    if "moe/router" in path or path.endswith("router"):
        return spec(fsdp, None)                       # (d, E)
    if "moe/shared" in path:
        if path.endswith("wd"):
            return spec(tp, fsdp)                     # (ds, d)
        return spec(fsdp, tp)                         # (d, ds)
    if "moe/" in path:
        # Expert-TP (default): every expert's FFN is sharded over "model"
        # (d_expert) and "data" (d_model).  Unlike expert-parallel (E over
        # "model"), this needs no dispatch all-to-all and no divisibility
        # of E (60 experts on a 16-way axis).
        # REPRO_MOE_SHARDING=ep switches to expert-parallel (E on "model",
        # d_model on fsdp) -- the §Perf B hillclimb comparison.
        import os
        if os.environ.get("REPRO_MOE_SHARDING", "tp") == "ep":
            if path.endswith("wd"):
                return spec(tp, None, fsdp)           # (E, de, d)
            return spec(tp, fsdp, None)               # (E, d, de)
        if path.endswith("wd"):
            return spec(None, tp, fsdp)               # (E, de, d)
        return spec(None, fsdp, tp)                   # (E, d, de)

    # --- MLA ---
    if path.endswith(("wdq", "wdkv")):
        return spec(fsdp, None)                       # (d, r)
    if path.endswith(("wuq", "wuk", "wuv")):
        return spec(None, tp, None)                   # (r, h, e)

    # --- attention ---
    if path.endswith(("attn/wq", "attn/wk", "attn/wv", "xattn/wq",
                      "xattn/wk", "xattn/wv")):
        return spec(fsdp, tp, None)                   # (d, h, dh)
    if path.endswith(("attn/wo", "xattn/wo")):
        return spec(tp, None, fsdp)                   # (h, dh, d)
    if path.endswith(("bq", "bk", "bv")):
        return spec(tp, None)                         # (h, dh)

    # --- MLP ---
    if path.endswith(("mlp/wg", "mlp/wu", "mlp/wi", "cmix/wk")):
        return spec(fsdp, tp)                         # (d, ff)
    if path.endswith(("mlp/wd", "mlp/wo", "cmix/wv")):
        return spec(tp, fsdp)                         # (ff, d)

    # --- SSM / RWKV ---
    if path.endswith("ssm/win"):
        return spec(fsdp, tp)
    if path.endswith("ssm/wout"):
        return spec(tp, fsdp)
    if path.endswith(("tmix/wr", "tmix/wk", "tmix/wv", "tmix/wg", "cmix/wr")):
        return spec(fsdp, tp)                         # (d, d)
    if path.endswith("tmix/wo"):
        return spec(tp, fsdp)
    if path.endswith("w_lora_a"):
        return spec(fsdp, None)
    if path.endswith("w_lora_b"):
        return spec(None, fsdp)

    # --- embeddings / heads ---
    if path.endswith(("embed", "unembed")):
        # vocab-parallel only: FSDP on d here puts the "data" axis on the
        # contraction dim of the CE dots whose batch dim is also "data",
        # which pushes GSPMD into full-vocab all-gathers in the CE backward
        # (9.3 GiB/chip at 152k vocab).  Vocab/16 already makes the table
        # small (<200 MB/chip for every assigned arch).
        return spec(tp, None)                         # (V, d)
    if path.endswith("mtp/fuse"):
        return spec(fsdp, None)

    # --- everything else (norms, biases, scalars): replicate ---
    return P()


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(params_shape, mesh):
    """NamedSharding tree matching a params (shape-)tree."""

    def leaf(kp, x):
        return NamedSharding(mesh, param_spec(_path_str(kp), x.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def opt_state_shardings(opt_shape, mesh, params_shape=None):
    """Optimizer-state sharding.

    fp32 m/v mirror the param spec.  int8 block-quantized leaves ("q",
    "scale") are flat (blocks, 128)/(blocks, 1): shard the block dim over
    *all* mesh axes when divisible (fully-sharded optimizer state, the
    deepseek-v3 fit requirement), else replicate.
    """
    all_axes = tuple(mesh.axis_names)

    def leaf(kp, x):
        path = _path_str(kp)
        if path.endswith("step"):
            return NamedSharding(mesh, P())
        # strip the leading "m/"/"v/" and any quantized-leaf suffix so the
        # state leaf reuses its param's rules (q/scale keep the param shape,
        # so the same spec applies; scale's smaller last dim is re-checked
        # for divisibility by param_spec itself).
        sub = path.split("/", 1)[1] if "/" in path else path
        for suffix in ("/q", "/scale", "/f"):
            if sub.endswith(suffix):
                sub = sub[: -len(suffix)]
                break
        return NamedSharding(mesh, param_spec(sub, x.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, opt_shape)


def batch_shardings(batch_shape, mesh):
    """Input batch: shard the leading (batch) dim over ("pod","data")."""
    fsdp = batch_axes(mesh)

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        ax = _fit(mesh, x.shape[0], fsdp)
        return NamedSharding(mesh, P(*((ax,) + (None,) * (x.ndim - 1))))

    return jax.tree_util.tree_map(leaf, batch_shape)


def cache_shardings(cache_shape, mesh, cfg=None):
    """Decode caches: (L, B, S, H, Dh) -- batch on fsdp, heads on model.

    For batch=1 (long_500k) the batch axis falls back to replication via the
    divisibility check; heads still shard on "model".
    """
    fsdp = batch_axes(mesh)
    tp = model_axis(mesh)

    def leaf(kp, x):
        name = _path_str(kp).rsplit("/", 1)[-1]
        b_ax = _fit(mesh, x.shape[1], fsdp) if x.ndim >= 2 else None
        if name in ("k_scale", "v_scale"):      # (L, B, S, Hkv)
            h_ax = _fit(mesh, x.shape[3], tp)
            return NamedSharding(mesh, P(None, b_ax, None, h_ax))
        if name in ("k", "v", "xk", "xv"):      # (L, B, S, Hkv, D)
            h_ax = _fit(mesh, x.shape[3], tp)
            if h_ax is not None:
                return NamedSharding(mesh, P(None, b_ax, None, h_ax, None))
            # kv heads too few for the model axis: sequence-shard the cache
            # (flash-decoding style; softmax becomes distributed max/sum)
            s_ax = _fit(mesh, x.shape[2], tp)
            return NamedSharding(mesh, P(None, b_ax, s_ax, None, None))
        if name in ("ssm", "state"):            # (L, B, H, N/dk, P/dv)
            h_ax = _fit(mesh, x.shape[2], tp)
            return NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        if name == "latent":                    # (L, B, S, kvr+dr)
            w_ax = _fit(mesh, x.shape[3], tp)
            return NamedSharding(mesh, P(None, b_ax, None, w_ax))
        if x.ndim == 3:                         # (L, B, d) shift carries
            return NamedSharding(mesh, P(None, b_ax, None))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)
