"""Fault tolerance: heartbeats, straggler mitigation, elastic re-mesh.

This container has one host, so multi-host failure handling is expressed as
mechanism + simulation hooks (exercised by tests/test_fault_tolerance.py):

  * HeartbeatMonitor -- wall-clock heartbeats per worker; a worker silent for
    ``timeout`` is declared dead.  On real clusters the transport is the
    coordination service (jax.distributed / etcd); here it is injectable.
  * StragglerMitigator -- per-step duration tracking; workers slower than
    ``factor`` x median over a window are flagged.  Because the data pipeline
    is counter-based (data/pipeline.py), a flagged worker's shard can be
    reassigned by *renumbering shards*, no data motion needed.
  * plan_elastic_remesh -- on node loss, shrink the "data" axis to the
    largest feasible size and return the new DataConfig sharding; parameters
    are FSDP-sharded over ("pod","data") so the restore path is a standard
    checkpoint load with the new mesh (checkpoints store full arrays).
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step_times: list


class HeartbeatMonitor:
    def __init__(self, workers, timeout: float = 60.0, clock=time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.workers = {w: WorkerState(clock(), []) for w in workers}

    def beat(self, worker, step_time: float | None = None):
        st = self.workers[worker]
        st.last_beat = self.clock()
        if step_time is not None:
            st.step_times.append(step_time)
            del st.step_times[:-32]

    def dead(self):
        now = self.clock()
        return [w for w, st in self.workers.items()
                if now - st.last_beat > self.timeout]


class StragglerMitigator:
    def __init__(self, factor: float = 2.0, window: int = 8):
        self.factor = factor
        self.window = window

    def stragglers(self, monitor: HeartbeatMonitor):
        med = self._median([
            st.step_times[-1] for st in monitor.workers.values()
            if st.step_times])
        if med is None:
            return []
        out = []
        for w, st in monitor.workers.items():
            recent = st.step_times[-self.window:]
            if len(recent) >= self.window // 2 and \
                    self._median(recent) > self.factor * med:
                out.append(w)
        return out

    @staticmethod
    def _median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2] if xs else None


def plan_elastic_remesh(n_alive: int, model_parallel: int = 16):
    """Largest (data, model) mesh fitting ``n_alive`` chips, model fixed.

    Returns (data, model) or None if even one model group does not fit.
    Growing back after repair is the same operation in reverse; since the
    data pipeline is counter-based, shard renumbering is free.
    """
    data = n_alive // model_parallel
    if data < 1:
        return None
    # prefer powers of two for collective efficiency
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel)


def reassign_shards(n_shards: int, dead: list[int]) -> dict[int, int]:
    """Deterministic shard reassignment: dead worker w's shard moves to
    alive worker (w + k) % n; with counter-based data, the assignee simply
    starts calling ``batch_at`` with the extra shard id."""
    alive = [w for w in range(n_shards) if w not in dead]
    mapping = {}
    for i, w in enumerate(dead):
        mapping[w] = alive[i % len(alive)]
    return mapping
