"""Fault tolerance: heartbeats, straggler mitigation, elastic re-mesh.

This container has one host, so multi-host failure handling is expressed as
mechanism + simulation hooks (exercised by tests/test_fault_tolerance.py):

  * HeartbeatMonitor -- wall-clock heartbeats per worker; a worker silent for
    ``timeout`` is declared dead.  On real clusters the transport is the
    coordination service (jax.distributed / etcd); here it is injectable.
  * StragglerMitigator -- per-step duration tracking; workers slower than
    ``factor`` x median over a window are flagged.  Because the data pipeline
    is counter-based (data/pipeline.py), a flagged worker's shard can be
    reassigned by *renumbering shards*, no data motion needed.
  * plan_elastic_remesh -- on node loss, shrink the "data" axis to the
    largest feasible size and return the new DataConfig sharding; parameters
    are FSDP-sharded over ("pod","data") so the restore path is a standard
    checkpoint load with the new mesh (checkpoints store full arrays).
  * RecoveryPolicy / with_retries -- what a consumer does when a read fails:
    transient IO errors are retried with exponential backoff, persistent
    corruption is raised / skipped / zero-filled per ``on_error``.  The store
    reader, checkpoint restore, and KV pager all resolve their policy from
    the codec config (``CodecConfig.recovery`` / ``io_retries`` /
    ``io_backoff``) with per-call overrides.
"""

from __future__ import annotations

import dataclasses
import time

VALID_RECOVERY = ("raise", "skip", "zero_fill")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """What a store/checkpoint/paging consumer does when a read fails.

    ``on_error`` applies to *persistent* failures (corruption, truncation,
    decode-guard trips): ``"raise"`` propagates the named error, ``"skip"``
    omits the failed entry (callers report it as quarantined), and
    ``"zero_fill"`` substitutes zeros of the recorded shape/dtype.

    ``retries``/``backoff``/``multiplier`` apply to *transient* IO errors
    (``OSError``): the read is retried with exponential backoff before the
    failure is treated as persistent.
    """

    on_error: str = "raise"
    retries: int = 0
    backoff: float = 0.05
    multiplier: float = 2.0

    def __post_init__(self):
        if self.on_error not in VALID_RECOVERY:
            raise ValueError(
                f"on_error must be one of {VALID_RECOVERY}, "
                f"got {self.on_error!r}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.backoff < 0:
            raise ValueError(f"backoff must be >= 0, got {self.backoff}")

    @classmethod
    def resolve(cls, policy, config=None):
        """Normalise ``policy`` (None | str | RecoveryPolicy) to an instance.

        ``None`` inherits from ``config`` (a ``CodecConfig``-like object with
        ``recovery``/``io_retries``/``io_backoff``) when given, else the
        defaults.  A bare string sets ``on_error`` and keeps the config's
        retry settings.
        """
        if isinstance(policy, cls):
            return policy
        kw = {}
        if config is not None:
            kw = dict(on_error=getattr(config, "recovery", "raise"),
                      retries=getattr(config, "io_retries", 0),
                      backoff=getattr(config, "io_backoff", 0.05))
        if policy is not None:
            kw["on_error"] = policy
        return cls(**kw)


def with_retries(fn, policy: RecoveryPolicy | None = None, *,
                 retry_on=(OSError,), sleep=time.sleep, on_retry=None):
    """Call ``fn()``; retry transient failures per ``policy``.

    Only exceptions in ``retry_on`` are retried -- deterministic corruption
    (``StoreCorruptError`` etc.) re-raises immediately since re-reading the
    same bad bytes cannot help.  ``on_retry(attempt, exc)`` is invoked before
    each sleep (used for degradation counters).  The final failure is
    re-raised unchanged.
    """
    policy = policy or RecoveryPolicy()
    delay = policy.backoff
    for attempt in range(policy.retries + 1):
        try:
            return fn()
        except retry_on as e:
            if attempt >= policy.retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            if delay > 0:
                sleep(delay)
            delay *= policy.multiplier


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step_times: list


class HeartbeatMonitor:
    def __init__(self, workers, timeout: float = 60.0, clock=time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.workers = {w: WorkerState(clock(), []) for w in workers}

    def beat(self, worker, step_time: float | None = None):
        st = self.workers[worker]
        st.last_beat = self.clock()
        if step_time is not None:
            st.step_times.append(step_time)
            del st.step_times[:-32]

    def dead(self):
        now = self.clock()
        return [w for w, st in self.workers.items()
                if now - st.last_beat > self.timeout]


class StragglerMitigator:
    def __init__(self, factor: float = 2.0, window: int = 8):
        self.factor = factor
        self.window = window

    def stragglers(self, monitor: HeartbeatMonitor):
        med = self._median([
            st.step_times[-1] for st in monitor.workers.values()
            if st.step_times])
        if med is None:
            return []
        out = []
        for w, st in monitor.workers.items():
            recent = st.step_times[-self.window:]
            if len(recent) >= self.window // 2 and \
                    self._median(recent) > self.factor * med:
                out.append(w)
        return out

    @staticmethod
    def _median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2] if xs else None


def plan_elastic_remesh(n_alive: int, model_parallel: int = 16):
    """Largest (data, model) mesh fitting ``n_alive`` chips, model fixed.

    Returns (data, model) or None if even one model group does not fit.
    Growing back after repair is the same operation in reverse; since the
    data pipeline is counter-based, shard renumbering is free.
    """
    data = n_alive // model_parallel
    if data < 1:
        return None
    # prefer powers of two for collective efficiency
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel)


def reassign_shards(n_shards: int, dead: list[int]) -> dict[int, int]:
    """Deterministic shard reassignment: dead worker w's shard moves to
    alive worker (w + k) % n; with counter-based data, the assignee simply
    starts calling ``batch_at`` with the extra shard id."""
    alive = [w for w in range(n_shards) if w not in dead]
    mapping = {}
    for i, w in enumerate(dead):
        mapping[w] = alive[i % len(alive)]
    return mapping
