"""Compressed collectives: error-feedback quantized gradient sync.

The paper's thesis -- keep tensors entropy/precision-reduced while they move
through a bandwidth-limited channel -- applied to the DP gradient reduction:

  baseline  : all-reduce fp32          -> 8 B/param wire cost (2x traffic)
  compressed: reduce-scatter bf16 (2B) -> quantize int8+scale (1B, error
              feedback) -> all-gather int8  => ~3 B/param, 2.7x reduction

Error feedback keeps the quantization residual per shard and folds it into
the next step's gradient, which preserves SGD convergence (Karimireddy et
al., 2019).  Exactness property tests live in tests/test_collectives.py.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_ef(x, residual, eb: float):
    """Error-feedback int8 lattice quantization of a tensor.

    Returns (codes int8, new_residual).  |dequant - (x + residual)| <= eb
    wherever |x + residual| < 127 * 2eb; saturated mass stays in the
    residual and re-enters next step.
    """
    target = x.astype(jnp.float32) + residual
    q = jnp.clip(jnp.round(target / (2 * eb)), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * (2 * eb)
    return q, target - deq


def dequantize(q, eb: float):
    return q.astype(jnp.float32) * (2 * eb)


def compressed_psum_mean(g, axis_name: str, residual, eb: float = 0.0):
    """Inside shard_map: mean-reduce ``g`` over ``axis_name`` with a
    bf16 reduce-scatter + int8 all-gather wire format.

    The int8 step uses a *dynamic per-shard scale* (max|shard|/127, shipped
    alongside the codes -- 4 B per shard, negligible) so the scheme is
    magnitude-free; ``eb`` > 0 optionally floors the scale, making the
    per-element error bound explicit.  Error feedback keeps what rounding
    drops.  g: local f32/bf16 gradient shard (same shape on every member).
    Returns (mean_g f32, new_residual)."""
    n = jax.lax.psum(1, axis_name)
    flat = g.reshape(-1).astype(jnp.bfloat16)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, flat.dtype)])
    # phase 1: bf16 reduce-scatter (each member owns 1/n of the sum)
    mine = jax.lax.psum_scatter(flat, axis_name, scatter_dimension=0,
                                tiled=True)
    target = mine.astype(jnp.float32) / n + residual
    scale = jnp.maximum(jnp.max(jnp.abs(target)) / 127.0, eb)
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_res = target - q.astype(jnp.float32) * scale
    # phase 2: all-gather int8 codes + per-shard scales
    gathered = jax.lax.all_gather(q, axis_name, axis=0, tiled=True)
    scales = jax.lax.all_gather(scale[None], axis_name, axis=0, tiled=True)
    per_elem = jnp.repeat(scales, gathered.shape[0] // scales.shape[0])
    out = gathered.astype(jnp.float32) * per_elem
    if pad:
        out = out[:-pad]
    return out.reshape(g.shape), new_res


def make_dp_gradient_sync(mesh, eb: float = 1e-6):
    """Returns (sync_fn, init_residuals) for explicit-DP training loops.

    sync_fn(grads, residuals) -> (mean_grads, residuals); grads is a pytree
    of *local* (per data shard) gradients.  Used by
    examples/grad_compression_dp.py and the fault-tolerance integration
    test; the GSPMD path quantifies the wire saving analytically in
    EXPERIMENTS.md §Perf.
    """
    from jax.experimental.shard_map import shard_map

    n = mesh.shape["data"]

    def residual_shape(g):
        flat = g.size
        return jnp.zeros(((flat + (-flat) % n) // n,), jnp.float32)

    def init_residuals(grads):
        return jax.tree.map(residual_shape, grads)

    def _sync_one(g, r):
        return compressed_psum_mean(g, "data", r, eb)

    @partial(shard_map, mesh=mesh,
             in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")))
    def _sync_stacked(gs, rs):
        out, nr = _sync_one(gs[0], rs[0])
        return out[None], nr[None]

    def sync(grads, residuals):
        outs = []
        new_res = []
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(residuals)):
            # one shard_map per leaf keeps specs simple; leaves are stacked
            # over the data axis by the caller
            o, nr = _sync_stacked(g, r)
            outs.append(o)
            new_res.append(nr)
        tdef = jax.tree.structure(grads)
        return tdef.unflatten(outs), tdef.unflatten(new_res)

    return sync, init_residuals


def wire_bytes(n_params: int, scheme: str) -> int:
    """Analytic per-step DP wire traffic per member (ring algorithms)."""
    if scheme == "allreduce_f32":
        return 2 * 4 * n_params          # reduce-scatter + all-gather, fp32
    if scheme == "allreduce_bf16":
        return 2 * 2 * n_params
    if scheme == "rs_bf16_ag_int8":
        return 2 * n_params + 1 * n_params
    raise ValueError(scheme)
