"""Lightweight in-model sharding constraints.

GSPMD propagates shardings from jit boundaries, but long chains (embedding
gather -> rope -> chunked attention -> chunked CE) give it freedom to pick
batch-replicated layouts that blow past HBM (observed: 9 GiB full-batch CE
logits and 7 GiB full-batch rope intermediates on qwen3 train_4k).  The
model code pins the canonical layout -- batch on ("pod","data"), heads /
vocab / ffn on "model" -- through this module; everything no-ops when no
mesh is registered (unit tests, single-device runs).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH = "__batch__"
MODEL = "__model__"

_MESH = None


def set_mesh(mesh):
    """Register the mesh used to materialize constraints (None to clear)."""
    global _MESH
    _MESH = mesh


def _resolve(token):
    if token == BATCH:
        axes = tuple(a for a in ("pod", "data") if a in _MESH.axis_names)
        return axes if axes else None
    if token == MODEL:
        return "model" if "model" in _MESH.axis_names else None
    return token


def _fits(x, dim, axes):
    if axes is None:
        return None
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= _MESH.shape[a]
    return axes if (dim % n == 0 and dim >= n) else None


def shard(x, *spec):
    """with_sharding_constraint(x, P(*spec)) with BATCH/MODEL tokens.

    Dims whose size does not divide the axis fall back to unconstrained.
    """
    if _MESH is None:
        return x
    resolved = tuple(
        _fits(x, x.shape[i], _resolve(s)) if s is not None else None
        for i, s in enumerate(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*resolved)))
