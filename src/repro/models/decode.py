"""Single-token decode paths with per-layer caches (serve_step substrate).

Cache layouts (stacked over layers, scan-carried):
  dense/vlm/moe : k,v   (L, B, S_kv, Hkv, Dh)   -- S_kv = window for SWA
  mla_moe       : latent (L, B, S_kv, kvr + dr) -- compressed latent cache
  hybrid_ssm    : ssm (L, B, H, N, P) fp32  + attn k,v (n_attn, B, S, Hkv, Dh)
  rwkv          : state (L, B, H, dk, dv) fp32 + shift carries (L, B, d) x2
  encdec        : self k,v (L, B, S, Hkv, Dh) + cross k,v precomputed
                  (L, B, T_enc, Hkv, Dh)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM
from repro.models.config import ModelConfig


def cache_spec(cfg: ModelConfig, batch: int, kv_len: int, dtype=None):
    """Shape/dtype tree of the decode cache (also used by input_specs())."""
    dt = dtype or cfg.cdt
    fam = cfg.family
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.swa_window:
        kv_len = min(kv_len, cfg.swa_window)
    if fam in ("dense", "vlm"):
        if cfg.kv_quant:
            return {
                "k": ((cfg.n_layers, batch, kv_len, hkv, dh), jnp.int8),
                "v": ((cfg.n_layers, batch, kv_len, hkv, dh), jnp.int8),
                "k_scale": ((cfg.n_layers, batch, kv_len, hkv), jnp.float32),
                "v_scale": ((cfg.n_layers, batch, kv_len, hkv), jnp.float32),
            }
        return {"k": ((cfg.n_layers, batch, kv_len, hkv, dh), dt),
                "v": ((cfg.n_layers, batch, kv_len, hkv, dh), dt)}
    if fam == "moe":
        n = cfg.n_layers
        return {"k": ((n, batch, kv_len, hkv, dh), dt),
                "v": ((n, batch, kv_len, hkv, dh), dt)}
    if fam == "mla_moe":
        width = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {"latent": ((cfg.n_layers, batch, kv_len, width), dt)}
    if fam == "hybrid_ssm":
        dv, h, p = SSM.ssm_dims(cfg)
        n_attn = max(1, cfg.n_layers // max(cfg.hybrid_attn_every, 1))
        return {
            "ssm": ((cfg.n_layers, batch, h, cfg.ssm_state, p), jnp.float32),
            "k": ((n_attn, batch, kv_len, hkv, dh), dt),
            "v": ((n_attn, batch, kv_len, hkv, dh), dt),
        }
    if fam == "rwkv":
        h, dh_r = RWKV.rwkv_dims(cfg)
        return {
            "state": ((cfg.n_layers, batch, h, dh_r, dh_r), jnp.float32),
            "tshift": ((cfg.n_layers, batch, cfg.d_model), dt),
            "cshift": ((cfg.n_layers, batch, cfg.d_model), dt),
        }
    if fam == "encdec":
        n = cfg.n_layers
        return {
            "k": ((n, batch, kv_len, hkv, dh), dt),
            "v": ((n, batch, kv_len, hkv, dh), dt),
            "xk": ((n, batch, cfg.encoder_seq, hkv, dh), dt),
            "xv": ((n, batch, cfg.encoder_seq, hkv, dh), dt),
        }
    raise ValueError(fam)


def init_cache(cfg: ModelConfig, batch: int, kv_len: int):
    return {k: jnp.zeros(shape, dt)
            for k, (shape, dt) in cache_spec(cfg, batch, kv_len).items()}


# ---------------------------------------------------------------------------
# Per-family decode
# ---------------------------------------------------------------------------


def _dense_decode_layer(x, lp, cfg, ck, cv, pos, window=None, enc_feats=None):
    h, ck, cv = A.decode_attn(L.rms_norm(x, lp["ln1"]), lp["attn"], cfg,
                              ck, cv, pos, window=window)
    x = x + h
    if enc_feats is not None:
        xk, xv = enc_feats
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        xn = L.rms_norm(x, lp["ln_x"])
        q = jnp.einsum("bsd,dhe->bshe", xn, lp["xattn"]["wq"].astype(x.dtype))
        b = x.shape[0]
        g = hq // hkv
        s = jnp.einsum("bqhgd,bkhd->bhgqk",
                       q.reshape(b, 1, hkv, g, dh).astype(jnp.float32),
                       xk.astype(jnp.float32)) * dh ** -0.5
        a_ = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", a_,
                       xv.astype(jnp.float32)).reshape(b, 1, hq, dh)
        x = x + jnp.einsum("bshe,hed->bsd", o.astype(x.dtype),
                           lp["xattn"]["wo"].astype(x.dtype))
    x = x + L.mlp_apply(L.rms_norm(x, lp["ln2"]), lp["mlp"], cfg.act)
    return x, ck, cv


def forward_decode(params, token, cache, pos, cfg: ModelConfig):
    """token: (B, 1) int32; pos: int32 scalar (current absolute position).

    Returns (logits (B, 1, V), new_cache).
    """
    x = params["embed"][token].astype(cfg.cdt)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "vlm") and cfg.kv_quant:
        def body(x, inp):
            lp, kc = inp
            h, kc = A.decode_attn_int8(L.rms_norm(x, lp["ln1"]), lp["attn"],
                                       cfg, kc, pos, window=cfg.swa_window)
            x = x + h
            x = x + L.mlp_apply(L.rms_norm(x, lp["ln2"]), lp["mlp"], cfg.act)
            return x, kc

        kcache = {k: cache[k] for k in ("k", "v", "k_scale", "v_scale")}
        x, kc = jax.lax.scan(body, x, (params["layers"], kcache))
        new_cache.update(kc)

    elif fam in ("dense", "vlm", "moe"):
        def body(x, inp):
            lp, ck, cv = inp
            if fam == "moe":
                h, ck, cv = A.decode_attn(L.rms_norm(x, lp["ln1"]),
                                          lp["attn"], cfg, ck, cv, pos,
                                          window=cfg.swa_window)
                x = x + h
                mo, _ = MOE.moe_block(L.rms_norm(x, lp["ln2"]), lp["moe"], cfg)
                x = x + mo
            else:
                x, ck, cv = _dense_decode_layer(x, lp, cfg, ck, cv, pos,
                                                window=cfg.swa_window)
            return x, (ck, cv)

        layers = params["layers"]
        if fam == "moe" and "dense_layers" in params:
            raise NotImplementedError  # qwen2-moe has no dense prefix
        x, kv = jax.lax.scan(body, x, (layers, cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = kv

    elif fam == "mla_moe":
        nk = cfg.first_k_dense
        lat = cache["latent"]

        def dbody(x, inp):
            lp, lat_l = inp
            h, lat_l = MLA.mla_decode(L.rms_norm(x, lp["ln1"]), lp["attn"],
                                      cfg, lat_l, pos)
            x = x + h
            x = x + L.mlp_apply(L.rms_norm(x, lp["ln2"]), lp["mlp"], cfg.act)
            return x, lat_l

        def mbody(x, inp):
            lp, lat_l = inp
            h, lat_l = MLA.mla_decode(L.rms_norm(x, lp["ln1"]), lp["attn"],
                                      cfg, lat_l, pos)
            x = x + h
            mo, _ = MOE.moe_block(L.rms_norm(x, lp["ln2"]), lp["moe"], cfg)
            return x + mo, lat_l

        lat_dense, lat_moe = lat[:nk], lat[nk:]
        if nk:
            x, lat_dense = jax.lax.scan(dbody, x,
                                        (params["dense_layers"], lat_dense))
        x, lat_moe = jax.lax.scan(mbody, x, (params["layers"], lat_moe))
        new_cache["latent"] = jnp.concatenate([lat_dense, lat_moe], axis=0) \
            if nk else lat_moe

    elif fam == "hybrid_ssm":
        every = max(cfg.hybrid_attn_every, 1)
        shared = params["shared_attn"]
        n_attn = max(1, cfg.n_layers // every)

        # scan over ssm layers; attention caches are indexed by invocation.
        def body(carry, inp):
            x, idx, ck_all, cv_all = carry
            lp, sstate = inp
            xn = L.rms_norm(x, lp["ln1"])
            h, sstate = SSM.ssm_decode(xn, lp["ssm"], cfg, sstate)
            x = x + h

            def with_attn(args):
                x, ck_all, cv_all = args
                inv = jnp.minimum(idx // every, n_attn - 1)
                ck = ck_all[inv]
                cv = cv_all[inv]
                h, ck, cv = A.decode_attn(L.rms_norm(x, shared["ln1"]),
                                          shared["attn"], cfg, ck, cv, pos)
                x = x + h
                x = x + L.mlp_apply(L.rms_norm(x, shared["ln2"]),
                                    shared["mlp"], cfg.act)
                ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, inv, 0)
                cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, inv, 0)
                return x, ck_all, cv_all

            use_attn = (idx % every) == (every - 1)
            x, ck_all, cv_all = jax.lax.cond(
                use_attn, with_attn, lambda a: a, (x, ck_all, cv_all))
            return (x, idx + 1, ck_all, cv_all), sstate

        (x, _, ck_all, cv_all), sstates = jax.lax.scan(
            body, (x, jnp.int32(0), cache["k"], cache["v"]),
            (params["layers"], cache["ssm"]))
        new_cache["ssm"] = sstates
        new_cache["k"], new_cache["v"] = ck_all, cv_all

    elif fam == "rwkv":
        def body(x, inp):
            lp, st, ts, cs = inp
            y, ts, st = RWKV.time_mix(L.rms_norm(x, lp["ln1"]), ts, st,
                                      lp["tmix"], cfg)
            x = x + y
            y, cs = RWKV.channel_mix(L.rms_norm(x, lp["ln2"]), cs,
                                     lp["cmix"], cfg)
            return x + y, (st, ts, cs)

        x, (st, ts, cs) = jax.lax.scan(
            body, x, (params["layers"], cache["state"], cache["tshift"],
                      cache["cshift"]))
        new_cache["state"], new_cache["tshift"], new_cache["cshift"] = \
            st, ts, cs

    elif fam == "encdec":
        def body(x, inp):
            lp, ck, cv, xk, xv = inp
            x, ck, cv = _dense_decode_layer(x, lp, cfg, ck, cv, pos,
                                            enc_feats=(xk, xv))
            return x, (ck, cv)

        x, kv = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                       cache["v"], cache["xk"], cache["xv"]))
        new_cache["k"], new_cache["v"] = kv
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"])
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, unembed.astype(x.dtype))
    return logits, new_cache
