"""Attention: GQA / MQA, sliding-window, qk-norm, cross-attention, KV cache.

Training/prefill uses a blockwise (flash-style) formulation: queries are
processed in chunks with running max/sum softmax so the materialized score
block is (chunk, S) instead of (S, S).  XLA keeps the chunk loop as a scan;
on TPU the chunk matmuls hit the MXU at full tile occupancy.

Decode uses a dense one-token attention over the KV cache (optionally a ring
buffer of the last ``swa_window`` entries for sliding-window models).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.runtime import pspec

NEG_INF = -1e30


def init_attn(key, cfg: ModelConfig, bias: bool | None = None):
    d, dh = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    bias = cfg.qkv_bias if bias is None else bias
    ks = L.split_keys(key, 6)
    p = {
        "wq": L.dense_init(ks[0], (d, nq, dh), cfg.pdt),
        "wk": L.dense_init(ks[1], (d, nkv, dh), cfg.pdt),
        "wv": L.dense_init(ks[2], (d, nkv, dh), cfg.pdt),
        "wo": L.dense_init(ks[3], (nq, dh, d), cfg.pdt),
    }
    if bias:
        p["bq"] = jnp.zeros((nq, dh), cfg.pdt)
        p["bk"] = jnp.zeros((nkv, dh), cfg.pdt)
        p["bv"] = jnp.zeros((nkv, dh), cfg.pdt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), cfg.pdt)
        p["k_norm"] = jnp.ones((dh,), cfg.pdt)
    return p


def _project_qkv(x, p, cfg: ModelConfig, positions, kv_x=None):
    """Returns q (B,S,Hq,D), k,v (B,Skv,Hkv,D) with rope + qk-norm applied."""
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", kv_x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if "q_norm" in p:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    q = pspec.shard(q, pspec.BATCH, None, pspec.MODEL, None)
    k = pspec.shard(k, pspec.BATCH, None, pspec.MODEL, None)
    v = pspec.shard(v, pspec.BATCH, None, pspec.MODEL, None)
    if positions is not None:
        if cfg.mrope:
            q = L.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = L.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, hq: int):
    """Expand kv heads to the full query head count.

    Keeps every einsum on an (B, S, Hq, D) layout whose head dim is always
    divisible by the "model" mesh axis -- GQA's raw kv head count (2..8)
    usually is not, and letting GSPMD discover that mid-graph reshards
    activations to replicated-batch/head-split (observed: +55 GiB temps and
    1.3 GiB of all-to-all per step on qwen3).  The repeat is free under TP:
    each model shard materializes only its own head group.
    """
    hkv = k.shape[2]
    if hkv == hq:
        return k
    return jnp.repeat(k, hq // hkv, axis=2)


def blockwise_attn(q, k, v, *, causal: bool, chunk: int,
                   window: int | None = None, q_offset: int = 0):
    """Flash-style chunked attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``window``: sliding-window radius (keys older than ``window`` masked).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill=0).
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]          # MLA: value head dim != qk head dim
    k = repeat_kv(k, hq)
    v = repeat_kv(v, hq)
    q = q * (d ** -0.5)

    qh = pspec.shard(q.transpose(0, 2, 1, 3),
                     pspec.BATCH, pspec.MODEL, None, None)  # (B, H, S, D)
    kh = pspec.shard(k.transpose(0, 2, 1, 3),
                     pspec.BATCH, pspec.MODEL, None, None)
    vh = pspec.shard(v.transpose(0, 2, 1, 3),
                     pspec.BATCH, pspec.MODEL, None, None)

    n_chunks = max(sq // chunk, 1)
    chunk = sq // n_chunks
    kv_pos = jnp.arange(skv)

    # The chunk body is itself checkpointed so the (chunk, S) score block is
    # re-materialized in the backward pass instead of being saved for every
    # chunk -- the flash-attention memory profile, at XLA level.
    @jax.checkpoint
    def do_chunk(carry, i):
        qc = jax.lax.dynamic_slice_in_dim(qh, i * chunk, chunk, axis=2)
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, kh,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((chunk, skv), bool)
        if causal:
            mask &= kv_pos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m).astype(q.dtype)
        num = jnp.einsum("bhqk,bhkd->bhqd", e, vh,
                         preferred_element_type=jnp.float32)
        den = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        out = num / jnp.maximum(den, 1e-30)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(do_chunk, None, jnp.arange(n_chunks))
    # outs: (n_chunks, B, H, chunk, Dv) -> (B, S, Hq, Dv)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, hq, dv)
    return pspec.shard(out, pspec.BATCH, None, pspec.MODEL, None)


def attn_block(x, p, cfg: ModelConfig, positions, *, causal=True,
               kv_x=None, window=None):
    q, k, v = _project_qkv(x, p, cfg, positions, kv_x=kv_x)
    out = blockwise_attn(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                         window=window if window is not None else cfg.swa_window)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVLayout:
    """Static description of a layer's KV cache."""

    kv_len: int
    n_kv_heads: int
    head_dim: int


def _quantize_heads(x):
    """Per-(batch, pos, head) symmetric int8: x (B,S,H,D) -> (q, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=False) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decode_attn_int8(x, p, cfg: ModelConfig, cache, pos, *, window=None):
    """Single-token decode over an int8-quantized KV cache (§Perf C).

    The cache stores int8 codes + per-(pos, head) scales; scores use a true
    int8 x int8 -> int32 dot (the paper's thesis -- keep the in-memory
    working set compressed and decode on access -- applied to attention:
    the HBM read per step is 1 B/element instead of 2).
    cache: dict with k/v int8 (B,S,Hkv,D) and k_scale/v_scale (B,S,Hkv).
    Returns (out, new_cache_parts...).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32) if not cfg.mrope else \
        jnp.broadcast_to(pos, (b, 3, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(x, p, cfg, positions)

    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    d = cfg.head_dim
    ck, cv = cache["k"], cache["v"]
    s_cache = ck.shape[1]
    slot = pos % s_cache if window else jnp.minimum(pos, s_cache - 1)
    kq, ks = _quantize_heads(k)
    vq, vs = _quantize_heads(v)
    upd = jax.lax.dynamic_update_slice_in_dim
    ck = upd(ck, kq, slot, axis=1)
    cv = upd(cv, vq, slot, axis=1)
    cks = upd(cache["k_scale"], ks, slot, axis=1)
    cvs = upd(cache["v_scale"], vs, slot, axis=1)

    qq, qs = _quantize_heads(q)                       # (B,1,Hq,D)
    qg = qq.reshape(b, 1, hkv, g, d)
    s_i32 = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.int32),
                       ck.astype(jnp.int32))
    qs_g = qs.reshape(b, 1, hkv, g)
    s = s_i32.astype(jnp.float32) * \
        jnp.einsum("bqhg,bkh->bhgqk", qs_g, cks) * (d ** -0.5)
    kv_pos = jnp.arange(s_cache)
    valid = (kv_pos <= pos) | (jnp.bool_(bool(window)) & (pos >= s_cache))
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd,bkh->bqhgd", a, cv.astype(jnp.float32),
                     cvs)
    out = out.reshape(b, 1, hq, d).astype(x.dtype)
    return (jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype)),
            {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs})


def decode_attn(x, p, cfg: ModelConfig, cache_k, cache_v, pos, *,
                window=None):
    """Single-token decode.

    x: (B, 1, d); cache_k/v: (B, S, Hkv, D); pos: int32[] current position.
    Returns (out (B,1,d), new_k, new_v).  For sliding-window models the
    cache is a ring buffer of size ``window`` (cache slot = pos % window).
    """
    b, _, _ = x.shape
    s_cache = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32) if not cfg.mrope else \
        jnp.broadcast_to(pos, (b, 3, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(x, p, cfg, positions)

    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    d = cfg.head_dim
    slot = pos % s_cache if window else jnp.minimum(pos, s_cache - 1)
    # Caches stay at hkv heads (no repeat: an 8x-repeated 32k cache is 8x
    # the HBM traffic per step).  When hkv does not divide the model axis
    # the cache is sequence-sharded instead (runtime/sharding.py) and the
    # softmax reductions below become distributed max/sum.
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    qg = q.reshape(b, 1, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, cache_k,
                   preferred_element_type=jnp.float32) * d ** -0.5
    kv_pos = jnp.arange(s_cache)
    if window:
        # Ring buffer: every written slot holds one of the last `s_cache`
        # positions, so all slots are valid once the buffer has wrapped;
        # before that, only slots <= pos have been written.
        valid = (kv_pos <= pos) | (pos >= s_cache)
    else:
        valid = kv_pos <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", a, cache_v).reshape(b, 1, hq, d)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype)), \
        cache_k, cache_v
