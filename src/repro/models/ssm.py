"""Mamba2 (SSD) blocks -- chunked state-space duality formulation.

The scalar-per-head decay makes the chunked algorithm numerically safe: all
pairwise decay factors are exp(cumA_t - cumA_s) <= 1 for t >= s, so the
(Q, Q) intra-chunk matrices never overflow (unlike per-channel-decay models,
see rwkv.py).  Structure follows the Mamba2 paper's reference:

  intra:  Y[t] += sum_{s<=t in chunk} (C_t . B_s) * exp(A[s+1..t]) * X[s]
  state:  S_c   = sum_{s in chunk} exp(A[s+1..end]) * B_s (x) X_s
  inter:  Y[t] += C_t . (decay * S_{c-1}) * exp(A[chunk_start..t])

Decode keeps the (B, H, P, N) recurrent state: h = dA*h + dt*x (x) B.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def ssm_dims(cfg: ModelConfig):
    p_head = 64
    dv = 2 * cfg.d_model                      # expand factor 2
    h = cfg.ssm_heads if cfg.ssm_heads else dv // p_head
    p_head = dv // h
    return dv, h, p_head


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    n = cfg.ssm_state
    dv, h, _p = ssm_dims(cfg)
    ks = L.split_keys(key, 4)
    return {
        # fused in-projection: [x (dv) | z (dv) | B (n) | C (n) | dt (h)]
        "win": L.dense_init(ks[0], (d, 2 * dv + 2 * n + h), cfg.pdt),
        "wout": L.dense_init(ks[1], (dv, d), cfg.pdt),
        "a_log": jnp.zeros((h,), jnp.float32),       # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((dv,), cfg.pdt),
    }


def _segsum(a):
    """Lower-triangular pairwise sums: out[t, s] = sum_{s < u <= t} a[u]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x, b_in, c_in, dt, a, chunk: int):
    """Chunked SSD.

    x:  (B, S, H, P)   values
    b_in, c_in: (B, S, N)  input/output projections (shared across heads)
    dt: (B, S, H)      softplus'd step sizes
    a:  (H,)           negative decay rates
    Returns y: (B, S, H, P).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    # clamp the chunk to the sequence (short decode-consistency prompts)
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    q = chunk

    xs = x.reshape(bsz, nc, q, h, p)
    bs = b_in.reshape(bsz, nc, q, n)
    cs = c_in.reshape(bsz, nc, q, n)
    dts = dt.reshape(bsz, nc, q, h)
    da = dts * a[None, None, None, :]                  # (B,nc,Q,H) log-decay
    da = jnp.moveaxis(da, -1, -2)                      # (B,nc,H,Q)

    # intra-chunk
    lmat = jnp.exp(_segsum(da))                        # (B,nc,H,Q,Q)
    cb = jnp.einsum("bcqn,bckn->bcqk", cs, bs)         # (B,nc,Q,Q)
    scores = cb[:, :, None] * lmat                     # (B,nc,H,Q,Q)
    xdt = xs * dts[..., None]                          # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xdt)

    # chunk states: S_c = sum_s exp(cum_end - cum_s) * dtB_s (x) X_s
    cum = jnp.cumsum(da, axis=-1)                      # (B,nc,H,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)        # (B,nc,H,Q)
    sstate = jnp.einsum("bchq,bcqn,bcqhp->bchnp",
                        decay_to_end, bs, xdt)         # (B,nc,H,N,P)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[..., -1])                # (B,nc,H)

    def step(carry, inp):
        s_prev = carry
        s_c, dec = inp
        s_new = s_prev * dec[..., None, None] + s_c
        return s_new, s_prev

    sstate_t = jnp.moveaxis(sstate, 1, 0)              # (nc,B,H,N,P)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)          # (nc,B,H)
    init = jnp.zeros_like(sstate_t[0])
    _, s_prevs = jax.lax.scan(step, init, (sstate_t, decay_t))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)              # (B,nc,H,N,P) state entering chunk

    # inter-chunk contribution
    decay_in = jnp.exp(cum)                            # (B,nc,H,Q) decay from chunk start
    y_inter = jnp.einsum("bcqn,bchq,bchnp->bcqhp", cs, decay_in, s_prevs)

    return (y_intra + y_inter).reshape(bsz, s, h, p)


def ssm_block(x, p, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d)."""
    bsz, s, d = x.shape
    n = cfg.ssm_state
    dv, h, ph = ssm_dims(cfg)
    proj = x @ p["win"].astype(x.dtype)
    xv, z, b_in, c_in, dt = jnp.split(
        proj, [dv, 2 * dv, 2 * dv + n, 2 * dv + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xv.reshape(bsz, s, h, ph)
    y = ssd_scan(xh.astype(jnp.float32), b_in.astype(jnp.float32),
                 c_in.astype(jnp.float32), dt, a, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, dv).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["wout"].astype(x.dtype)


def ssm_decode(x, p, cfg: ModelConfig, state):
    """Single-token decode.  state: (B, H, N, P) fp32."""
    bsz, _, d = x.shape
    n = cfg.ssm_state
    dv, h, ph = ssm_dims(cfg)
    proj = x[:, 0] @ p["win"].astype(x.dtype)
    xv, z, b_in, c_in, dt = jnp.split(
        proj, [dv, 2 * dv, 2 * dv + n, 2 * dv + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                          # (B,H)
    xh = xv.reshape(bsz, h, ph).astype(jnp.float32)
    xdt = xh * dt[..., None]
    state = state * da[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", b_in.astype(jnp.float32), xdt)
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, dv).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z[:, None]), p["norm"])
    return y @ p["wout"].astype(x.dtype), state
