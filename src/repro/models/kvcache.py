"""Compressed KV-cache blocks: the paper's in-memory-compression use case.

Decode caches are large, cold beyond the active window, and tolerant of
bounded error -- exactly the profile of the paper's RTM / GAMESS in-memory
workloads.  ``compress_cache`` SZ-compresses (Lorenzo+Huffman) each cache
tensor; ``decompress_cache`` restores it with the optimized parallel decoder
(gap-array by default -- the encoder is ours, so coupling is free; see paper
§V-C for the self-sync trade-off).

Along the sequence axis a KV cache is smooth per channel (adjacent tokens'
keys correlate), so the 1-D Lorenzo predictor applied along S gets ratios
well above the raw-entropy floor.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import api as sz


@dataclasses.dataclass
class CompressedCache:
    blobs: dict           # name -> core.sz.Compressed
    orig_dtypes: dict
    orig_shapes: dict

    @property
    def compressed_bytes(self) -> int:
        return sum(c.compressed_bytes for c in self.blobs.values())

    @property
    def original_bytes(self) -> int:
        return sum(int(np.prod(s)) * 2 for s in self.orig_shapes.values())

    @property
    def ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)


def compress_cache(cache: dict, eb: float = 1e-3,
                   skip: tuple = ()) -> CompressedCache:
    """Compress every tensor of a decode cache (relative error bound).

    The cache layout (L, B, S, H, D) is flattened with S innermost-adjacent
    to channels so the Lorenzo predictor sees token-to-token continuity.
    """
    blobs, dts, shapes = {}, {}, {}
    for name, arr in cache.items():
        if name in skip:
            continue
        x = np.asarray(arr, np.float32)
        blobs[name] = sz.compress(x, eb=eb, mode="rel")
        dts[name] = str(arr.dtype)
        shapes[name] = arr.shape
    return CompressedCache(blobs, dts, shapes)


def decompress_cache(cc: CompressedCache, method: str = "gap",
                     backend: str = "ref") -> dict:
    """Restore every cache tensor via the class-batched decoder.

    All blocks decode in one ``decompress_batch`` call -- one decode-write
    dispatch per CR class across the whole cache, not per tensor.
    """
    names = list(cc.blobs)
    xs = sz.decompress_batch([cc.blobs[n] for n in names], method=method,
                             backend=backend)
    return {n: jnp.asarray(np.asarray(x), jnp.dtype(cc.orig_dtypes[n]))
            for n, x in zip(names, xs)}
