"""Compressed KV-cache blocks: the paper's in-memory-compression use case.

Decode caches are large, cold beyond the active window, and tolerant of
bounded error -- exactly the profile of the paper's RTM / GAMESS in-memory
workloads.  ``compress_cache`` SZ-compresses (Lorenzo+Huffman) each cache
tensor; ``decompress_cache`` restores it with the optimized parallel decoder
(gap-array by default -- the encoder is ours, so coupling is free; see paper
§V-C for the self-sync trade-off).

Along the sequence axis a KV cache is smooth per channel (adjacent tokens'
keys correlate), so the 1-D Lorenzo predictor applied along S gets ratios
well above the raw-entropy floor.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.codec import Codec, default_codec
from repro.store.paging import KVPager  # noqa: F401  (re-export)


@dataclasses.dataclass
class CompressedCache:
    blobs: dict           # name -> core.sz.Compressed
    orig_dtypes: dict
    orig_shapes: dict

    @property
    def compressed_bytes(self) -> int:
        return sum(c.compressed_bytes for c in self.blobs.values())

    @property
    def original_bytes(self) -> int:
        return sum(int(np.prod(s)) * 2 for s in self.orig_shapes.values())

    @property
    def ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)


def compress_cache(cache: dict, codec: "Codec | None" = None,
                   skip: tuple = ()) -> CompressedCache:
    """Compress every tensor of a decode cache through one ``Codec``.

    The cache layout (L, B, S, H, D) is flattened with S innermost-adjacent
    to channels so the Lorenzo predictor sees token-to-token continuity.
    ``codec`` defaults to ``repro.core.default_codec()`` (the paper's
    relative 1e-3 bound).
    """
    codec = codec if codec is not None else default_codec()
    picked = {n: np.asarray(a, np.float32) for n, a in cache.items()
              if n not in skip}
    blobs = codec.compress_tree(picked)
    return CompressedCache(
        blobs,
        {n: str(cache[n].dtype) for n in picked},
        {n: cache[n].shape for n in picked})


def decompress_cache(cc: CompressedCache,
                     codec: "Codec | None" = None) -> dict:
    """Restore every cache tensor via the codec's class-batched decoder.

    All blocks decode in one ``decompress_tree`` call -- one decode-write
    dispatch per CR class across the whole cache, not per tensor -- with
    phase 1-3 plans served from the codec's cache on repeats.
    """
    codec = codec if codec is not None else default_codec()
    xs = codec.decompress_tree(cc.blobs)
    # Cast on device: decode_batch already produced device arrays, so the
    # dtype cast must not bounce them through host memory.
    return {n: jnp.asarray(x, jnp.dtype(cc.orig_dtypes[n]))
            for n, x in xs.items()}


# ---------------------------------------------------------------------------
# Block paging through the compressed tensor store (serve --kv-offload)
# ---------------------------------------------------------------------------


def offload_prefix(cache: dict, pager: KVPager, n_tokens: int,
                   block_tokens: int = 64, keys=None):
    """Evict tokens [0, n_tokens) of the cache in fixed-size blocks.

    Each block becomes one store archive (one chunk per cache tensor,
    codebooks deduped); the evicted region of ``cache`` is zeroed.  Returns
    ``(cache, block_ids)`` in eviction order.
    """
    ids = []
    for lo in range(0, n_tokens, block_tokens):
        cache, bid = pager.offload(cache, lo, min(lo + block_tokens,
                                                  n_tokens), keys=keys)
        ids.append(bid)
    return cache, ids


def page_in_blocks(cache: dict, pager: KVPager, block_ids,
                   on_lost=None) -> dict:
    """Restore offloaded blocks into the cache (demand paging: call with
    whatever blocks the next attention window needs).

    ``on_lost(block_id, exc)`` turns a lost block (``PageLostError``:
    missing/corrupt archive -- already evicted and counted in
    ``pager.stats["pages_lost"]``) into degraded service: the callback is
    invoked, the block's span stays zeroed, and paging continues with the
    remaining blocks.  Without the callback the named error propagates.
    """
    from repro.store import PageLostError

    for bid in block_ids:
        try:
            cache = pager.page_in(cache, bid)
        except PageLostError as e:
            if on_lost is None:
                raise
            on_lost(bid, e)
    return cache


def page_in_blocks_batched(cache: dict, pager: KVPager, block_ids,
                           on_lost=None) -> dict:
    """Batched ``page_in_blocks``: stage every block, decode them all in ONE
    class-merged dispatch set (``KVPager.fetch_many``), then install each
    block at its original token span.

    Same loss semantics as ``page_in_blocks`` -- a lost block either raises
    ``PageLostError`` or is absorbed by ``on_lost(block_id, exc)`` with its
    span left zeroed -- but the decode cost is one ``decompress_batch`` over
    every tensor of every block instead of one dispatch chain per block.
    """
    decoded = pager.fetch_many(block_ids, on_lost=on_lost)
    for bid, tensors in decoded.items():
        meta = pager.block_meta(bid)
        span = ((slice(None),) * pager.seq_axis
                + (slice(meta["lo"], meta["hi"]),))
        for name, block in tensors.items():
            cache[name] = cache[name].at[span].set(
                jnp.asarray(block, cache[name].dtype))
    return cache
