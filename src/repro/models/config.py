"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | mla_moe | hybrid_ssm | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    d_head: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False                 # qwen3
    qkv_bias: bool = False                # qwen2.5 / qwen2-vl
    swa_window: Optional[int] = None      # h2o-danube sliding window
    rope_theta: float = 1e4
    mrope: bool = False                   # qwen2-vl M-RoPE (3 sections)
    mrope_sections: tuple = (16, 24, 24)  # t/h/w rotary sections (half-dims)
    tie_embeddings: bool = False
    act: str = "silu"                     # mlp activation (gelu for whisper/starcoder2)
    mlp_type: str = "gated"               # "gated" (SwiGLU) | "plain" (2-matrix)

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                     # per-expert hidden dim
    first_k_dense: int = 0                # deepseek-v3: first layers dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- MLA (deepseek-v3) ---
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    mtp: bool = False                     # multi-token-prediction extra head

    # --- SSM / hybrid (zamba2, rwkv6) ---
    ssm_state: int = 0                    # mamba2 state dim N
    ssm_heads: int = 0                    # mamba2 value heads
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0            # zamba2: shared attn block period

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500               # whisper-base post-conv frames

    # --- modality frontend stubs ---
    frontend: Optional[str] = None        # "vision_stub" | "audio_stub"

    # --- numerics / scaling ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 512                 # blockwise-attention query chunk
    kv_quant: bool = False                # int8 KV cache (decode; §Perf)
    rwkv_kernel: bool = False             # Pallas chunked-GLA time-mix

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_subquadratic(self) -> bool:
        """Can this architecture serve 500k-token contexts?  (DESIGN.md §5)"""
        return (self.family in ("hybrid_ssm", "rwkv")
                or self.swa_window is not None)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            d_head=32,
        )
        if self.moe:
            # capacity_factor 4: no token dropping at smoke-test scale, so
            # batched forward == step-by-step decode (capacity drops are
            # batch-size-dependent and would break the consistency tests)
            small.update(n_experts=min(self.n_experts, 8),
                         n_shared_experts=min(self.n_shared_experts, 1),
                         top_k=min(self.top_k, 2), d_expert=64,
                         first_k_dense=min(self.first_k_dense, 1),
                         capacity_factor=4.0)
        if self.mla:
            small.update(q_lora_rank=64, kv_lora_rank=32, qk_rope_dim=16,
                         qk_nope_dim=16, v_head_dim=32, d_head=32)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_heads=4, ssm_chunk=32)
        if self.hybrid_attn_every:
            small.update(hybrid_attn_every=2)
        if self.mrope:
            # rotary sections must sum to the reduced head_dim / 2
            small.update(mrope_sections=(4, 6, 6))
        if self.encoder_layers:
            small.update(encoder_layers=2, encoder_seq=64)
        if self.swa_window:
            small.update(swa_window=32)
        small.update(overrides)
        return dataclasses.replace(self, **small)


def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
    d, dh = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads

    def attn_params():
        if cfg.mla:
            q = d * cfg.q_lora_rank + cfg.q_lora_rank * nq * (
                cfg.qk_nope_dim + cfg.qk_rope_dim)
            kv = d * (cfg.kv_lora_rank + cfg.qk_rope_dim) + cfg.kv_lora_rank \
                * nq * (cfg.qk_nope_dim + cfg.v_head_dim)
            o = nq * cfg.v_head_dim * d
            return q + kv + o
        return d * dh * (nq + 2 * nkv) + nq * dh * d

    def mlp_params(ff):
        return (3 if cfg.mlp_type == "gated" else 2) * d * ff

    def moe_params():
        routed = cfg.n_experts * mlp_params(cfg.d_expert)
        shared = mlp_params(cfg.d_expert * cfg.n_shared_experts) \
            if cfg.n_shared_experts else 0
        return routed + shared + d * cfg.n_experts

    def ssm_params():
        # mamba2 block: in-proj [x|z|B|C|dt] + out-proj, expand factor 2
        dv = 2 * d
        return d * (2 * dv + 2 * cfg.ssm_state + cfg.ssm_heads) + dv * d

    total = cfg.vocab * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab * d

    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.family in ("moe", "mla_moe"):
        dense_l = cfg.first_k_dense
        moe_l = cfg.n_layers - dense_l
        total += cfg.n_layers * attn_params()
        total += dense_l * mlp_params(cfg.d_ff if not cfg.moe else
                                      cfg.d_expert * (cfg.top_k + cfg.n_shared_experts))
        total += moe_l * moe_params()
    elif cfg.family == "hybrid_ssm":
        # Mamba2 layers carry no separate MLP; d_ff belongs to the single
        # weight-shared attention block (Zamba2 design).
        total += cfg.n_layers * ssm_params()
        total += attn_params() + mlp_params(cfg.d_ff)
    elif cfg.family == "rwkv":
        # time-mix (r,k,v,g,w projections + decay mlp) + channel-mix
        total += cfg.n_layers * (6 * d * d + 2 * d * cfg.d_ff + d * cfg.d_ff)
    elif cfg.family == "encdec":
        total += cfg.encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
        # decoder has self + cross attention
        total += cfg.n_layers * (2 * attn_params() + mlp_params(cfg.d_ff))
    return int(total)
