"""train_step / serve_step -- the functions the launcher jits and shards."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import pspec

MTP_WEIGHT = 0.3


def cross_entropy(logits, labels):
    """Mean next-token CE over valid (label >= 0) positions."""
    valid = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def chunked_softmax_xent(hidden, unembed, labels, seq_chunk: int = 1024):
    """CE without materializing full (B, S, V) logits.

    Scans over sequence chunks; each chunk's (B, c, V) logits live only
    inside a checkpointed body.  Returns (sum_nll, n_valid).
    """
    b, s, d = hidden.shape
    n_chunks = max(s // seq_chunk, 1)
    c = s // n_chunks
    hc = hidden[:, : n_chunks * c].reshape(b, n_chunks, c, d)
    lc = labels[:, : n_chunks * c].reshape(b, n_chunks, c)

    @jax.checkpoint
    def body(carry, inp):
        h, lab = inp                       # (B, c, d), (B, c)
        h = pspec.shard(h, pspec.BATCH, None, None)
        logits = jnp.einsum("bcd,vd->bcv", h, unembed,
                            preferred_element_type=jnp.float32)
        logits = pspec.shard(logits, pspec.BATCH, None, pspec.MODEL)
        valid = lab >= 0
        safe = jnp.maximum(lab, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # Masked reduction instead of take_along_axis: a gather over the
        # model-sharded vocab dim makes GSPMD all-gather full-vocab logits
        # (9.3 GiB/chip on 152k vocab); the iota-mask reduces shard-locally.
        vocab_iota = jnp.arange(logits.shape[-1], dtype=jnp.int32)
        gold = jnp.sum(
            jnp.where(vocab_iota[None, None, :] == safe[..., None],
                      logits, 0.0), axis=-1)
        nll = jnp.where(valid, lse - gold, 0.0)
        s_nll, n_valid = carry
        return (s_nll + nll.sum(), n_valid + valid.sum()), None

    (s_nll, n_valid), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.int32(0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    # tail (if s % seq_chunk): fall back to direct computation
    if n_chunks * c < s:
        h_t = hidden[:, n_chunks * c:]
        l_t = labels[:, n_chunks * c:]
        logits = jnp.einsum("bcd,vd->bcv", h_t, unembed,
                            preferred_element_type=jnp.float32)
        valid = l_t >= 0
        safe = jnp.maximum(l_t, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        s_nll = s_nll + jnp.where(valid, lse - gold, 0.0).sum()
        n_valid = n_valid + valid.sum()
    return s_nll, n_valid


def loss_fn(params, batch, cfg: ModelConfig):
    hidden, aux = T.forward_hidden(params, batch["tokens"], cfg,
                                   extra_embeds=batch.get("extra_embeds"))
    unembed = params.get("unembed", params["embed"]).astype(hidden.dtype)
    s_nll, n_valid = chunked_softmax_xent(hidden, unembed, batch["labels"])
    loss = s_nll / jnp.maximum(n_valid, 1)
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp and "mtp" in params:
        # Reuse the pre-unembed hidden? Keep it simple: the MTP head runs on
        # the embedding stream (cheap surrogate block; DESIGN.md §5).
        hidden = params["embed"][batch["tokens"]].astype(cfg.cdt)
        mlogits = T.mtp_logits(params, batch["tokens"], hidden, cfg)
        mtp_labels = jnp.where(
            batch["labels"] >= 0,
            jnp.roll(batch["labels"], -1, axis=-1), -1).at[:, -1].set(-1)
        mtp_loss = cross_entropy(mlogits, mtp_labels)
        loss = loss + MTP_WEIGHT * mtp_loss
        metrics["mtp"] = mtp_loss
    return loss + aux, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    n_micro: int = 1, grad_shardings=None):
    """Training step with optional gradient accumulation.

    ``n_micro`` > 1 scans over micro-batches (leading batch dim split),
    accumulating f32 grads -- divides every activation / remat-stack buffer
    by n_micro at the cost of param-sized f32 accumulators.  Required to fit
    the >=70B train cells on 16 GB v5e.

    ``grad_shardings``: optional pytree of NamedShardings (the params'
    shardings) pinned onto the accumulators; without it GSPMD is free to
    replicate the f32 grad tree across the model axis (observed: +2.6
    TiB/device on deepseek-v3).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (l, met), g = grads_of(params, mb)
                acc = _pin(jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / n_micro,
                    acc, g))
                return acc, (l, met)

            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, (ls, mets) = jax.lax.scan(body, zeros, micro)
            loss = ls.mean()
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        params, opt_state, opt_metrics = adamw.update(
            grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg)
        return metrics

    return eval_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, token (B,1), cache, pos) -> logits, cache."""

    def serve_step(params, token, cache, pos):
        return D.forward_decode(params, token, cache, pos, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Prefill: run the training forward to produce logits for a prompt
    (cache construction for the dense path is exercised by serve.py)."""

    def prefill_step(params, tokens, extra_embeds=None):
        logits, _ = T.forward(params, tokens, cfg, extra_embeds=extra_embeds)
        return logits

    return prefill_step
