"""Shared neural-net layers (pure functions over param dicts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with an f32 reduction but no f32 materialization of x.

    Keeping x in bf16 end-to-end matters under remat: an ``x.astype(f32)``
    at the top of a checkpointed layer makes XLA save the *converted* f32
    copy of the (L, B, S, d) activation stack alongside the bf16 one
    (observed 2x saved-activation HBM on every train cell)."""
    dt = x.dtype
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv[..., None] * scale.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def gated_mlp(x, p, act: str = "silu"):
    """SwiGLU-style MLP: (act(x Wg) * (x Wu)) Wd."""
    a = act_fn(act)
    h = a(x @ p["wg"].astype(x.dtype)) * (x @ p["wu"].astype(x.dtype))
    return h @ p["wd"].astype(x.dtype)


def mlp2(x, p, act: str = "gelu"):
    """Plain 2-matrix MLP (whisper / starcoder2-style)."""
    h = act_fn(act)(x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


def mlp_apply(x, p, act: str = "silu"):
    """Dispatch on param keys: gated (wg/wu/wd) vs plain (wi/wo)."""
    return gated_mlp(x, p, act) if "wg" in p else mlp2(x, p, act)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                         # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    sin = jnp.sin(ang)[..., None, :]                   # (..., S, 1, D/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 1e4):
    """Qwen2-VL multimodal RoPE.

    positions3: (..., 3, S) -- temporal / height / width position ids.  The
    rotary half-dims are partitioned into ``sections`` (summing to D/2); each
    section rotates with its own position stream.  For pure-text tokens all
    three streams are equal and this reduces to standard RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)                          # (D/2,)
    # Select the position stream per frequency slot.
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)       # (D/2,)
    pos = jnp.moveaxis(positions3[..., sec_id, :], -2, -1)  # (..., S, D/2)
    ang = pos.astype(jnp.float32) * inv
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameter initialization helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
