"""RWKV-6 ("Finch") blocks: time-mix with data-dependent per-channel decay.

Per-channel decay forbids the SSD-style chunk factorization without log-space
rescaling games (1/decay overflows f32 across a chunk), so training uses an
exact per-step ``lax.scan`` over the sequence -- numerically identical to the
recurrent decode path.  A Pallas chunked-GLA kernel is the production TPU
path and is listed as a beyond-paper optimization in EXPERIMENTS.md §Perf.

Recurrence (head h, channels i->k, j->v):
  S_t = diag(w_t) S_{t-1} + k_t^T v_t
  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

HEAD_DIM = 64


def rwkv_dims(cfg: ModelConfig):
    h = cfg.d_model // HEAD_DIM
    return h, HEAD_DIM


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    h, dh = rwkv_dims(cfg)
    ks = L.split_keys(key, 8)
    return {
        "mix": 0.5 * jnp.ones((5, d), cfg.pdt),    # r,k,v,w,g token-shift mixes
        "wr": L.dense_init(ks[0], (d, d), cfg.pdt),
        "wk": L.dense_init(ks[1], (d, d), cfg.pdt),
        "wv": L.dense_init(ks[2], (d, d), cfg.pdt),
        "wg": L.dense_init(ks[3], (d, d), cfg.pdt),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),  # decay bias (w ~ exp(-exp(w0)))
        "w_lora_a": L.dense_init(ks[4], (d, 64), cfg.pdt),
        "w_lora_b": L.dense_init(ks[5], (64, d), cfg.pdt, scale=1e-2),
        "u": jnp.zeros((h, dh), jnp.float32),      # per-head bonus
        "wo": L.dense_init(ks[6], (d, d), cfg.pdt),
        "ln_x": jnp.ones((d,), cfg.pdt),
    }


def init_channel_mix(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    ks = L.split_keys(key, 3)
    return {
        "mix": 0.5 * jnp.ones((2, d), cfg.pdt),
        "wk": L.dense_init(ks[0], (d, ff), cfg.pdt),
        "wv": L.dense_init(ks[1], (ff, d), cfg.pdt),
        "wr": L.dense_init(ks[2], (d, d), cfg.pdt),
    }


def _token_shift(x, x_prev):
    """shifted[t] = x[t-1]; x_prev fills t=0 (decode carry)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _time_mix_proj(x, xs, p, cfg):
    d = cfg.d_model
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xs - x) * mix[i] for i in range(5))
    r = xr @ p["wr"].astype(x.dtype)
    k = xk @ p["wk"].astype(x.dtype)
    v = xv @ p["wv"].astype(x.dtype)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    logw = -jnp.exp(
        p["w0"] + (jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype))
                   @ p["w_lora_b"].astype(x.dtype)).astype(jnp.float32))
    w = jnp.exp(logw)                                  # (B,S,d) in (0,1)
    return r, k, v, g, w


def time_mix(x, x_prev, state, p, cfg: ModelConfig, chunk: int = 64):
    """x: (B,S,d); x_prev: (B,d) shift carry; state: (B,H,dk,dv) fp32.

    The recurrence runs as a two-level scan: an outer checkpointed scan over
    ``chunk``-step blocks (saving only block-boundary states -- S/chunk
    states instead of S, which is what keeps the backward pass inside HBM)
    with an exact inner per-step scan.  Returns (y, new_x_prev, new_state).
    """
    bsz, s, d = x.shape
    h, dh = rwkv_dims(cfg)
    xs = _token_shift(x, x_prev)
    r, k, v, g, w = _time_mix_proj(x, xs, p, cfg)

    rh = r.reshape(bsz, s, h, dh).astype(jnp.float32)
    kh = k.reshape(bsz, s, h, dh).astype(jnp.float32)
    vh = v.reshape(bsz, s, h, dh).astype(jnp.float32)
    wh = w.reshape(bsz, s, h, dh)
    u = p["u"]

    def step(s_prev, inp):
        rt, kt, vt, wt = inp                           # (B,H,dh)
        kv = kt[..., :, None] * vt[..., None, :]       # (B,H,dk,dv)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s_prev + u[..., None] * kv)
        s_new = wt[..., :, None] * s_prev + kv
        return s_new, y

    nc = max(s // chunk, 1)
    cs = s // nc

    def to_chunks(a):                                  # (B,S,H,dh)->(nc,cs,B,H,dh)
        return jnp.moveaxis(a, 1, 0).reshape(nc, cs, bsz, h, dh)

    seq = (to_chunks(rh), to_chunks(kh), to_chunks(vh), to_chunks(wh))

    @jax.checkpoint
    def chunk_fn(s_prev, inp):
        return jax.lax.scan(step, s_prev, inp)

    state, ys = jax.lax.scan(chunk_fn, state, seq)     # ys: (nc,cs,B,H,dh)
    y = jnp.moveaxis(ys.reshape(s, bsz, h, dh), 0, 1).reshape(bsz, s, d)
    y = y.astype(x.dtype)
    y = L.rms_norm(y, p["ln_x"]) * g
    y = y @ p["wo"].astype(x.dtype)
    return y, x[:, -1], state


def channel_mix(x, x_prev, p, cfg: ModelConfig):
    xs = _token_shift(x, x_prev)
    mix = p["mix"].astype(x.dtype)
    xk = x + (xs - x) * mix[0]
    xr = x + (xs - x) * mix[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * (
        k @ p["wv"].astype(x.dtype)), x[:, -1]
