"""Mixture-of-Experts block: shared + routed experts, top-k, capacity-based.

Dispatch is the sort-free capacity scheme: per-(token, expert) assignment
ranks are computed with an exclusive cumsum over the one-hot assignment
matrix; each expert keeps its first C tokens (GShard-style dropping).  The
(E, C, d) gather/scatter is what GSPMD turns into the EP all-to-all when
experts are sharded on the "model" axis (see runtime/sharding.py).

Aux load-balancing loss follows Switch: E * sum_e f_e * p_e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def init_moe(key, cfg: ModelConfig):
    d, de = cfg.d_model, cfg.d_expert
    e = cfg.n_experts
    ks = L.split_keys(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, e), jnp.float32),  # router in fp32
        "wg": L.dense_init(ks[1], (e, d, de), cfg.pdt),
        "wu": L.dense_init(ks[2], (e, d, de), cfg.pdt),
        "wd": L.dense_init(ks[3], (e, de, d), cfg.pdt),
    }
    if cfg.n_shared_experts:
        ds = de * cfg.n_shared_experts
        kss = L.split_keys(ks[4], 3)
        p["shared"] = {
            "wg": L.dense_init(kss[0], (d, ds), cfg.pdt),
            "wu": L.dense_init(kss[1], (d, ds), cfg.pdt),
            "wd": L.dense_init(kss[2], (ds, d), cfg.pdt),
        }
    return p


MOE_TOKEN_CHUNK = 65536


def moe_block(x, p, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    Long-sequence calls (prefill_32k pushes 1M tokens through each layer)
    are scanned in MOE_TOKEN_CHUNK-token chunks: the (T, E) routing tensors
    and (E, C, d) dispatch buffers scale with the chunk, not the sequence.
    Capacity becomes per-chunk (GShard-style local capacity).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    if t > MOE_TOKEN_CHUNK and t % MOE_TOKEN_CHUNK == 0:
        nc = t // MOE_TOKEN_CHUNK
        xc = xt.reshape(nc, MOE_TOKEN_CHUNK, d)

        def body(carry, xi):
            out, aux = _moe_tokens(xi, p, cfg)
            return carry + aux, out

        aux, outs = jax.lax.scan(body, jnp.float32(0), xc)
        return outs.reshape(b, s, d), aux / nc
    out, aux = _moe_tokens(xt, p, cfg)
    return out.reshape(b, s, d), aux


def _moe_tokens(xt, p, cfg: ModelConfig):
    """Dispatch/compute/combine for a flat (T, d) token block."""
    from repro.runtime import pspec

    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    # Pin expert weights (and thereby their cotangents) to the configured
    # layout: the dispatch gather replicates its output, and without this
    # the stacked MoE weight *gradients* inherit that replication -- 2.6
    # TiB/device on deepseek-v3 (f32 grads of 58 x 3 x (256,7168,2048)).
    import os
    if os.environ.get("REPRO_MOE_SHARDING", "tp") == "ep":
        wg = pspec.shard(p["wg"], pspec.MODEL, pspec.BATCH, None)
        wu = pspec.shard(p["wu"], pspec.MODEL, pspec.BATCH, None)
        wd = pspec.shard(p["wd"], pspec.MODEL, None, pspec.BATCH)
    else:
        wg = pspec.shard(p["wg"], None, pspec.BATCH, pspec.MODEL)
        wu = pspec.shard(p["wu"], None, pspec.BATCH, pspec.MODEL)
        wd = pspec.shard(p["wd"], None, pspec.MODEL, pspec.BATCH)

    logits = xt.astype(jnp.float32) @ p["router"]
    logits = pspec.shard(logits, pspec.BATCH, None)
    gates = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    topv, topi = jax.lax.top_k(gates, k)                     # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Capacity + ranks.  assign: (T, E) in {0,1}; rank = exclusive cumsum.
    cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
    assign = jnp.zeros((t, e), jnp.int32)
    assign = assign.at[jnp.arange(t)[:, None], topi].set(1)
    ranks = jnp.cumsum(assign, axis=0) - assign              # (T, E)

    # Token ids routed to each (expert, slot); empty slots -> t (dropped row).
    rk = jnp.take_along_axis(ranks, topi, axis=1)            # (T, k)
    keep = rk < cap
    ek_safe = jnp.where(keep, topi, e)                       # e => OOB, dropped
    tok_ids = jnp.full((e, cap), t, jnp.int32)
    tok_ids = tok_ids.at[ek_safe, jnp.clip(rk, 0, cap - 1)].set(
        jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None], (t, k)),
        mode="drop")

    xe = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)[tok_ids]
    # (E, C, d) expert GEMMs
    h = L.act_fn(cfg.act)(
        jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))) * \
        jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe.dtype))

    # Combine: scatter expert outputs back with gate weights.
    gate_at = jnp.zeros((t, e), jnp.float32)
    gate_at = gate_at.at[jnp.arange(t)[:, None], topi].set(topv)
    w = gate_at[jnp.clip(tok_ids, 0, t - 1),
                jnp.arange(e)[:, None]] * (tok_ids < t)
    out = jnp.zeros((t + 1, d), jnp.float32)
    out = out.at[tok_ids.reshape(-1)].add(
        (ye * w[..., None].astype(ye.dtype)).reshape(-1, d).astype(jnp.float32),
        mode="drop")
    out = out[:t].astype(xt.dtype)

    if cfg.n_shared_experts:
        out = out + L.gated_mlp(xt, p["shared"], cfg.act)

    # Switch aux loss.
    frac_tokens = jnp.mean(assign.astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(gates, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux
