"""Multi-head Latent Attention (DeepSeek-V3).

Queries and keys/values are projected through low-rank latents; only the
compressed latent c_kv (kv_lora_rank) plus the shared rotary key k_rope
(qk_rope_dim) are cached at decode time -- MLA *is* a learned KV-cache
compression, which interacts with this framework's error-bounded cache
compression (DESIGN.md §5: we optionally EB-compress the latent itself).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import blockwise_attn
from repro.models.config import ModelConfig


def init_mla(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = L.split_keys(key, 8)
    return {
        "wdq": L.dense_init(ks[0], (d, qr), cfg.pdt),
        "q_norm": jnp.ones((qr,), cfg.pdt),
        "wuq": L.dense_init(ks[1], (qr, h, dn + dr), cfg.pdt),
        "wdkv": L.dense_init(ks[2], (d, kvr + dr), cfg.pdt),
        "kv_norm": jnp.ones((kvr,), cfg.pdt),
        "wuk": L.dense_init(ks[3], (kvr, h, dn), cfg.pdt),
        "wuv": L.dense_init(ks[4], (kvr, h, dv), cfg.pdt),
        "wo": L.dense_init(ks[5], (h, dv, d), cfg.pdt),
    }


def _latents(x, p, cfg: ModelConfig, positions):
    """Project to q (B,S,H,dn+dr), c_kv (B,S,kvr), k_rope (B,S,1,dr)."""
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = L.rms_norm(x @ p["wdq"].astype(x.dtype), p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["wuq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv_full = x @ p["wdkv"].astype(x.dtype)
    c_kv = L.rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora_rank:][..., None, :]  # (B,S,1,dr)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    return q, c_kv, k_rope


def _expand_kv(c_kv, k_rope, p, cfg: ModelConfig, dtype):
    """Latent -> full k (B,S,H,dn+dr) and v (B,S,H,dv)."""
    h = cfg.n_heads
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["wuk"].astype(dtype))
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["wuv"].astype(dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1)
    return k, v


def mla_block(x, p, cfg: ModelConfig, positions):
    q, c_kv, k_rope = _latents(x, p, cfg, positions)
    k, v = _expand_kv(c_kv, k_rope, p, cfg, x.dtype)
    out = blockwise_attn(q, k, v, causal=True, chunk=cfg.attn_chunk)
    # v_head_dim may differ from qk dim; out is (B,S,H,dv)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))


def mla_decode(x, p, cfg: ModelConfig, cache_latent, pos):
    """Decode step with the compressed latent cache (absorbed matmuls).

    cache_latent: (B, S, kvr + dr) storing [c_kv | k_rope].  The up-projection
    W_uk is absorbed into the query and W_uv into the output, so the latent
    cache is attended *directly* -- per-step FLOPs/bytes scale with kvr+dr,
    never with H * (dn + dv).  This is the production MLA decode identity:
      score = (q_nope W_uk) . c_kv + q_rope . k_rope
      out   = (attn @ c_kv) W_uv W_o
    """
    b = x.shape[0]
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, c_kv, k_rope = _latents(x, p, cfg, positions)
    entry = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)
    cache_latent = jax.lax.dynamic_update_slice_in_dim(
        jnp.asarray(cache_latent), entry.astype(cache_latent.dtype), pos,
        axis=1)

    c_all = cache_latent[..., : cfg.kv_lora_rank].astype(x.dtype)   # (B,S,r)
    kr_all = cache_latent[..., cfg.kv_lora_rank:].astype(x.dtype)   # (B,S,dr)

    q_nope, q_rope = q[..., :dn], q[..., dn:]
    # keep the absorbed product in f32: a bf16 (B,1,H,kvr) intermediate
    # costs ~10% logit error vs the unabsorbed training path
    q_c = jnp.einsum("bqhn,rhn->bqhr", q_nope, p["wuk"],
                     preferred_element_type=jnp.float32)
    scores = (
        jnp.einsum("bqhr,bkr->bhqk", q_c, c_all.astype(jnp.float32))
        + jnp.einsum("bqhe,bke->bhqk", q_rope.astype(jnp.float32),
                     kr_all.astype(jnp.float32))
    ) * (dn + dr) ** -0.5
    valid = jnp.arange(cache_latent.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    a = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkr->bqhr", a,
                     c_all.astype(jnp.float32)).astype(x.dtype)
    v_ctx = jnp.einsum("bqhr,rhe->bqhe", ctx, p["wuv"].astype(x.dtype))
    return jnp.einsum("bshe,hed->bsd", v_ctx, p["wo"].astype(x.dtype)), \
        cache_latent
