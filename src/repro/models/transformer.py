"""Model assembly for every assigned architecture family.

All stacks scan over layers with stacked parameters (compile-time O(1) in
depth, FSDP all-gathers overlap with layer compute under XLA latency hiding)
and wrap the layer body in ``jax.checkpoint`` when cfg.remat.

Families:
  dense / vlm  -- GQA attention (+SWA/qk-norm/bias/M-RoPE) + gated MLP
  moe          -- GQA attention + shared/routed top-k MoE
  mla_moe      -- MLA attention + MoE (+ optional MTP head), DeepSeek-V3
  hybrid_ssm   -- Mamba2 blocks + weight-shared attention block every k
  rwkv         -- RWKV6 time-mix + channel-mix
  encdec       -- Whisper: bidirectional encoder over stubbed frames +
                  causal decoder with cross-attention
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.runtime import pspec


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_mlp(key, cfg: ModelConfig, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = L.split_keys(key, 3)
    if cfg.mlp_type == "plain":
        return {
            "wi": L.dense_init(ks[0], (d, ff), cfg.pdt),
            "wo": L.dense_init(ks[1], (ff, d), cfg.pdt),
        }
    return {
        "wg": L.dense_init(ks[0], (d, ff), cfg.pdt),
        "wu": L.dense_init(ks[1], (d, ff), cfg.pdt),
        "wd": L.dense_init(ks[2], (ff, d), cfg.pdt),
    }


def _init_dense_layer(key, cfg: ModelConfig, cross: bool = False):
    ks = L.split_keys(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "attn": A.init_attn(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
        "mlp": _init_mlp(ks[1], cfg),
    }
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), cfg.pdt)
        p["xattn"] = A.init_attn(ks[2], cfg)
    return p


def _init_moe_layer(key, cfg: ModelConfig, use_mla: bool):
    ks = L.split_keys(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "attn": MLA.init_mla(ks[0], cfg) if use_mla else A.init_attn(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
        "moe": MOE.init_moe(ks[1], cfg),
    }


def _init_ssm_layer(key, cfg: ModelConfig):
    # Mamba2 layers carry no separate MLP (Zamba2: the d_ff MLP lives in the
    # weight-shared attention block only).
    ks = L.split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "ssm": SSM.init_ssm(ks[0], cfg),
    }


def _init_rwkv_layer(key, cfg: ModelConfig):
    ks = L.split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "tmix": RWKV.init_time_mix(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
        "cmix": RWKV.init_channel_mix(ks[1], cfg),
    }


def _stack_init(layer_init, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, *args))(keys)


def init_model(key, cfg: ModelConfig):
    ks = L.split_keys(key, 8)
    d = cfg.d_model
    params = {
        "embed": L.dense_init(ks[0], (cfg.vocab, d), cfg.pdt, scale=0.02),
        "final_norm": jnp.ones((d,), cfg.pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ks[1], (cfg.vocab, d), cfg.pdt,
                                         scale=0.02)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _stack_init(_init_dense_layer, ks[2],
                                       cfg.n_layers, cfg)
    elif fam in ("moe", "mla_moe"):
        use_mla = fam == "mla_moe"
        nk = cfg.first_k_dense

        def _init_prefix_layer(k):
            # DeepSeek-V3: every layer uses MLA attention; only the FFN of
            # the first_k_dense layers is dense instead of MoE.
            kk = L.split_keys(k, 2)
            return {
                "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
                "attn": MLA.init_mla(kk[0], cfg) if use_mla
                else A.init_attn(kk[0], cfg),
                "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
                "mlp": _init_mlp(kk[1], cfg),
            }

        if nk:
            params["dense_layers"] = _stack_init(_init_prefix_layer, ks[3], nk)
        params["layers"] = _stack_init(
            lambda k: _init_moe_layer(k, cfg, use_mla), ks[2],
            cfg.n_layers - nk)
        if cfg.mtp:
            kk = L.split_keys(ks[4], 2)
            params["mtp"] = {
                "fuse": L.dense_init(kk[0], (2 * d, d), cfg.pdt),
                "block": _init_dense_layer(kk[1], cfg),
                "norm": jnp.ones((d,), cfg.pdt),
            }
    elif fam == "hybrid_ssm":
        params["layers"] = _stack_init(_init_ssm_layer, ks[2],
                                       cfg.n_layers, cfg)
        params["shared_attn"] = _init_dense_layer(ks[3], cfg)
    elif fam == "rwkv":
        params["layers"] = _stack_init(_init_rwkv_layer, ks[2],
                                       cfg.n_layers, cfg)
    elif fam == "encdec":
        params["encoder"] = _stack_init(_init_dense_layer, ks[3],
                                        cfg.encoder_layers, cfg)
        params["enc_norm"] = jnp.ones((d,), cfg.pdt)
        params["layers"] = _stack_init(
            lambda k: _init_dense_layer(k, cfg, cross=True), ks[2],
            cfg.n_layers)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# Layer bodies (training / prefill)
# ---------------------------------------------------------------------------


def _dense_layer(x, p, cfg: ModelConfig, positions, *, causal=True,
                 enc_out=None, window=None):
    h = A.attn_block(L.rms_norm(x, p["ln1"]), p["attn"], cfg, positions,
                     causal=causal, window=window)
    x = x + h
    if enc_out is not None:
        h = A.attn_block(L.rms_norm(x, p["ln_x"]), p["xattn"], cfg,
                         None, causal=False, kv_x=enc_out)
        x = x + h
    x = x + L.mlp_apply(L.rms_norm(x, p["ln2"]), p["mlp"], cfg.act)
    # Sequence-sharded layer boundary: the remat-saved per-layer stack
    # inherits this spec, cutting saved-activation HBM by the model-axis
    # degree (Megatron-SP layout between layers).
    return pspec.shard(x, pspec.BATCH, pspec.MODEL, None)


def _moe_layer(x, p, cfg: ModelConfig, positions, use_mla: bool):
    xn = L.rms_norm(x, p["ln1"])
    h = MLA.mla_block(xn, p["attn"], cfg, positions) if use_mla else \
        A.attn_block(xn, p["attn"], cfg, positions)
    x = x + h
    mo, aux = MOE.moe_block(L.rms_norm(x, p["ln2"]), p["moe"], cfg)
    return pspec.shard(x + mo, pspec.BATCH, pspec.MODEL, None), aux


def _ssm_layer(x, p, cfg: ModelConfig):
    x = x + SSM.ssm_block(L.rms_norm(x, p["ln1"]), p["ssm"], cfg)
    return pspec.shard(x, pspec.BATCH, pspec.MODEL, None)


def _rwkv_layer(x, p, cfg: ModelConfig):
    bsz = x.shape[0]
    h, dh = RWKV.rwkv_dims(cfg)
    zero_prev = jnp.zeros((bsz, cfg.d_model), x.dtype)
    state0 = jnp.zeros((bsz, h, dh, dh), jnp.float32)
    y, _, _ = RWKV.time_mix(L.rms_norm(x, p["ln1"]), zero_prev, state0,
                            p["tmix"], cfg)
    x = x + y
    y, _ = RWKV.channel_mix(L.rms_norm(x, p["ln2"]), zero_prev, p["cmix"], cfg)
    return pspec.shard(x + y, pspec.BATCH, pspec.MODEL, None)


# ---------------------------------------------------------------------------
# Forward (training / prefill): tokens -> logits
# ---------------------------------------------------------------------------


def _positions(cfg: ModelConfig, bsz: int, s: int):
    # Batch-free position vectors: identical across the batch at train /
    # prefill time, so keeping them (S,)-shaped keeps the rope sin/cos
    # tables tiny and replication-safe under GSPMD.
    pos = jnp.arange(s, dtype=jnp.int32)
    if cfg.mrope:
        # Text tokens: all three M-RoPE streams coincide (DESIGN.md §5);
        # the vision stub supplies patch embeddings with text-linear ids.
        return jnp.broadcast_to(pos[None, :], (3, s))
    return pos


def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def forward_hidden(params, tokens, cfg: ModelConfig, extra_embeds=None):
    """tokens: (B, S) int32; extra_embeds: (B, P, d) modality-stub embeddings
    prepended to the token embeddings (vlm patches / audio frames).

    Returns (hidden (B, S_total, d) post-final-norm, aux_loss scalar).
    """
    x = params["embed"][tokens].astype(cfg.cdt)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.cdt), x], axis=1)
    x = pspec.shard(x, pspec.BATCH, None, None)
    bsz, s, _ = x.shape
    positions = _positions(cfg, bsz, s)
    aux_total = jnp.float32(0)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        def body(x, lp):
            return _dense_layer(x, lp, cfg, positions), None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])

    elif fam in ("moe", "mla_moe"):
        use_mla = fam == "mla_moe"
        if "dense_layers" in params:
            def dbody(x, lp):
                xn = L.rms_norm(x, lp["ln1"])
                h = MLA.mla_block(xn, lp["attn"], cfg, positions) if use_mla \
                    else A.attn_block(xn, lp["attn"], cfg, positions)
                x = x + h
                x = x + L.mlp_apply(L.rms_norm(x, lp["ln2"]), lp["mlp"],
                                    cfg.act)
                return x, None
            x, _ = jax.lax.scan(_maybe_remat(dbody, cfg), x,
                                params["dense_layers"])

        def mbody(x, lp):
            x, aux = _moe_layer(x, lp, cfg, positions, use_mla)
            return x, aux
        x, auxs = jax.lax.scan(_maybe_remat(mbody, cfg), x, params["layers"])
        aux_total = aux_total + jnp.sum(auxs) * cfg.router_aux_coef

    elif fam == "hybrid_ssm":
        every = max(cfg.hybrid_attn_every, 1)
        shared = params["shared_attn"]

        def body(carry, inp):
            x, idx = carry
            x = _ssm_layer(x, inp, cfg)
            use_attn = (idx % every) == (every - 1)
            x = jax.lax.cond(
                use_attn,
                lambda x: _dense_layer(x, shared, cfg, positions),
                lambda x: x,
                x)
            return (x, idx + 1), None
        (x, _), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, jnp.int32(0)),
                                 params["layers"])

    elif fam == "rwkv":
        def body(x, lp):
            return _rwkv_layer(x, lp, cfg), None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])

    elif fam == "encdec":
        assert extra_embeds is not None, "encdec needs stub frame embeddings"
        enc = extra_embeds.astype(cfg.cdt)
        enc_pos = _positions(cfg, enc.shape[0], enc.shape[1])

        def ebody(h, lp):
            return _dense_layer(h, lp, cfg, enc_pos, causal=False), None
        enc, _ = jax.lax.scan(_maybe_remat(ebody, cfg), enc,
                              params["encoder"])
        enc = L.rms_norm(enc, params["enc_norm"])

        x = params["embed"][tokens].astype(cfg.cdt)
        dec_pos = _positions(cfg, bsz, tokens.shape[1])

        def dbody(h, lp):
            return _dense_layer(h, lp, cfg, dec_pos, enc_out=enc), None
        x, _ = jax.lax.scan(_maybe_remat(dbody, cfg), x, params["layers"])
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"])
    return x, aux_total


def forward(params, tokens, cfg: ModelConfig, extra_embeds=None):
    """Full forward: (logits (B, S_total, V), aux)."""
    x, aux = forward_hidden(params, tokens, cfg, extra_embeds=extra_embeds)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, unembed.astype(x.dtype))
    return logits, aux


def mtp_logits(params, tokens, hidden, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: predict t+2 from [h_t; e_{t+1}]."""
    if "mtp" not in params:
        return None
    p = params["mtp"]
    emb_next = params["embed"][tokens].astype(hidden.dtype)
    emb_next = jnp.roll(emb_next, -1, axis=1)
    fused = jnp.concatenate([hidden, emb_next], axis=-1) @ \
        p["fuse"].astype(hidden.dtype)
    positions = _positions(cfg, fused.shape[0], fused.shape[1])
    h = _dense_layer(fused, p["block"], cfg, positions)
    h = L.rms_norm(h, p["norm"])
    unembed = params.get("unembed", params["embed"])
    return jnp.einsum("bsd,vd->bsv", h, unembed.astype(h.dtype))
