"""Sharded checkpointing over the compressed tensor store.

Layout:  <dir>/step_<N>/{manifest.json, archive.szt, <flat-key>.npy}
Writes are atomic (tmp dir + rename) so a preempted save can never corrupt
the restore path -- the fault-tolerance tests kill a training process mid-run
and restart from ``latest_step``.

Compression policy lives in one ``repro.core.Codec`` handed to the
manager: its eb/mode quantize the float shards, its method/backend decode
them back, and its digest-keyed plan cache persists across restores.
Compressible float shards are packed into ONE ``repro.store`` archive per
step (chunked format, deduped codebooks, per-chunk CRC32) instead of N
loose files; restore streams the archive through the double-buffered
reader -- disk reads of chunk group N+1 overlap the class-batched decode of
group N -- and plan-cache hits on a re-restore skip the phase 1-3 rebuild.
Everything else is a raw ``.npy`` with its checksum recorded in
``manifest.json``; any corrupt or truncated shard surfaces as
``CheckpointIntegrityError`` naming the entry, never a numpy parse error.
"""

from __future__ import annotations

import concurrent.futures as futures
import json
import os
import shutil
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import Codec, default_codec
from repro.core.sz.compressor import Compressed
from repro.store import Archive, ArchiveWriter, StoreError

ARCHIVE_NAME = "archive.szt"
MANIFEST_VERSION = 2


class CheckpointIntegrityError(RuntimeError):
    """A checkpoint entry is missing, truncated, or fails its checksum."""


def _flatten(tree):
    flat = {}

    def rec(prefix, t):
        if isinstance(t, dict):
            for k, v in t.items():
                rec(f"{prefix}.{k}" if prefix else str(k), v)
        else:
            flat[prefix] = t

    rec("", tree)
    return flat


def _unflatten(flat):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _file_crc32(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(1 << 20)
            if not buf:
                return crc & 0xFFFFFFFF
            crc = zlib.crc32(buf, crc)


class _CrcTee:
    """File-object wrapper that CRCs bytes as they are written, so the raw
    save path never re-reads what it just wrote."""

    def __init__(self, f):
        self._f = f
        self.crc = 0

    def write(self, buf):
        self.crc = zlib.crc32(buf, self.crc) & 0xFFFFFFFF
        return self._f.write(buf)

    def __getattr__(self, name):
        return getattr(self._f, name)


class CheckpointManager:
    """Checkpoints over the store, with one ``Codec`` as the whole policy.

    ``codec=None`` saves raw shards only.  With a codec, float32 shards of
    at least ``compress_min_size`` elements compress under the codec's
    eb/mode into the step archive, and restores decode with the codec's
    method/backend -- re-restores hit its plan cache (phase 4 only).
    """

    def __init__(self, directory: str, codec: "Codec | None" = None,
                 compress_min_size: int = 65536, asynchronous: bool = False):
        self.dir = directory
        self.codec = codec
        self.min_size = compress_min_size
        os.makedirs(directory, exist_ok=True)
        self._pool = futures.ThreadPoolExecutor(1) if asynchronous else None
        self._pending = None

    @property
    def _read_codec(self) -> Codec:
        """Codec for the restore path: a raw-only manager can still read a
        compressed checkpoint through the default codec."""
        return self.codec if self.codec is not None else default_codec()

    # -- write --------------------------------------------------------------

    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        if self._pool is not None:
            self.wait()
            params = jax.tree.map(np.asarray, params)  # snapshot now
            opt_state = jax.tree.map(np.asarray, opt_state) if opt_state else None
            self._pending = self._pool.submit(
                self._save_sync, step, params, opt_state, extra)
            return
        self._save_sync(step, params, opt_state, extra)

    def _save_sync(self, step, params, opt_state, extra):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"version": MANIFEST_VERSION, "step": step,
                    "entries": {}, "extra": extra or {}}
        trees = {"params": params}
        if opt_state is not None:
            trees["opt"] = opt_state
        writer = None
        try:
            for tname, tree in trees.items():
                flat = {key: np.asarray(leaf)
                        for key, leaf in _flatten(tree).items()}
                if self.codec is not None:
                    # Tree-level compression: every float32 shard above the
                    # size floor becomes a Compressed leaf in one codec call.
                    flat = self.codec.compress_tree(flat,
                                                    min_size=self.min_size)
                for key, leaf in flat.items():
                    fname = f"{tname}.{key}"
                    if isinstance(leaf, Compressed):
                        if writer is None:
                            writer = ArchiveWriter(
                                os.path.join(tmp, ARCHIVE_NAME),
                                codec=self.codec)
                        writer.add(fname, leaf,
                                   orig_dtype=str(np.dtype(leaf.dtype)))
                        manifest["entries"][fname] = {"kind": "sz"}
                    else:
                        path = os.path.join(tmp, fname + ".npy")
                        with open(path, "wb") as f:
                            tee = _CrcTee(f)
                            np.save(tee, leaf, allow_pickle=False)
                        manifest["entries"][fname] = {
                            "kind": "raw", "dtype": str(leaf.dtype),
                            "checksum": tee.crc}
        except BaseException:
            if writer is not None:
                writer.abort()
            raise
        if writer is not None:
            for fname, crc in writer.checksums().items():
                manifest["entries"][fname]["checksum"] = crc
            writer.close()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- read ---------------------------------------------------------------

    def latest_step(self):
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def _restore_archive(self, d: str, step: int, manifest) -> dict:
        """Decode every compressed entry of a step's archive (integrity-
        checked, plan-cached, I/O overlapped with decode)."""
        sz_entries = {fname: meta for fname, meta in
                      manifest["entries"].items() if meta["kind"] == "sz"}
        if not sz_entries:
            return {}
        apath = os.path.join(d, ARCHIVE_NAME)
        if not os.path.exists(apath):
            raise CheckpointIntegrityError(
                f"step {step}: manifest lists {len(sz_entries)} compressed "
                f"entries but {ARCHIVE_NAME} is missing")
        try:
            with Archive(apath, codec=self._read_codec) as ar:
                for fname, meta in sz_entries.items():
                    if fname not in ar:
                        raise CheckpointIntegrityError(
                            f"step {step}: entry {fname!r} missing from "
                            f"{ARCHIVE_NAME}")
                    want = meta.get("checksum")
                    if want is not None and ar.chunk(fname).crc32 != want:
                        raise CheckpointIntegrityError(
                            f"step {step}: entry {fname!r} checksum in "
                            f"manifest.json disagrees with {ARCHIVE_NAME}")
                return ar.read_all(list(sz_entries))
        except StoreError as e:
            raise CheckpointIntegrityError(
                f"step {step}: {ARCHIVE_NAME} is corrupt or truncated: "
                f"{e}") from e

    def _restore_raw(self, d: str, step: int, fname: str, meta):
        path = os.path.join(d, fname + ".npy")
        if not os.path.exists(path):
            raise CheckpointIntegrityError(
                f"step {step}: raw shard {fname!r} is missing")
        want = meta.get("checksum")
        if want is not None and _file_crc32(path) != want:
            raise CheckpointIntegrityError(
                f"step {step}: raw shard {fname!r} failed its checksum "
                f"(corrupt or truncated file)")
        try:
            return jnp.asarray(np.load(path, allow_pickle=False))
        except ValueError as e:
            raise CheckpointIntegrityError(
                f"step {step}: raw shard {fname!r} is unreadable: {e}") from e

    def restore(self, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        version = manifest.get("version", 1)
        if version > MANIFEST_VERSION:
            raise CheckpointIntegrityError(
                f"step {step}: manifest version {version} is newer than this "
                f"reader (supports <= {MANIFEST_VERSION})")
        if version < MANIFEST_VERSION and any(
                m["kind"] == "sz" for m in manifest["entries"].values()):
            raise CheckpointIntegrityError(
                f"step {step}: checkpoint uses the pre-store manifest "
                f"version {version} (loose .szblob.npz shards); re-save it "
                f"with this manager's writer -- it is not corrupt")
        trees: dict = {"params": {}, "opt": {}}
        sz_restored = self._restore_archive(d, step, manifest)
        for fname, meta in manifest["entries"].items():
            tname, key = fname.split(".", 1)
            if meta["kind"] == "sz":
                arr = sz_restored[fname]
            else:
                arr = self._restore_raw(d, step, fname, meta)
            trees.setdefault(tname, {})[key] = arr
        params = _unflatten(trees["params"])
        opt = _unflatten(trees["opt"]) if trees.get("opt") else None
        return {"step": step, "params": params, "opt": opt,
                "extra": manifest.get("extra", {})}
