"""Sharded checkpointing over the compressed tensor store.

Layout:  <dir>/step_<N>/{manifest.json, archive.szt, <flat-key>.npy}
Writes are atomic (tmp dir + rename) so a preempted save can never corrupt
the restore path -- the fault-tolerance tests kill a training process mid-run
and restart from ``latest_step``.

Compression policy lives in one ``repro.core.Codec`` handed to the
manager: its eb/mode quantize the float shards, its method/backend decode
them back, and its digest-keyed plan cache persists across restores.
Compressible float shards are packed into ONE ``repro.store`` archive per
step (chunked format, deduped codebooks, per-chunk CRC32) instead of N
loose files; restore streams the archive through the double-buffered
reader -- disk reads of chunk group N+1 overlap the class-batched decode of
group N -- and plan-cache hits on a re-restore skip the phase 1-3 rebuild.
Everything else is a raw ``.npy`` with its checksum recorded in
``manifest.json``; any corrupt or truncated shard surfaces as
``CheckpointIntegrityError`` naming the entry, never a numpy parse error.
"""

from __future__ import annotations

import concurrent.futures as futures
import json
import os
import shutil
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import Codec, default_codec
from repro.core.huffman import pipeline as hp
from repro.core.sz.compressor import Compressed
from repro.distributed.restore import ShardedRestorer
from repro.distributed.shards import ShardedWriter
from repro.store import Archive, ArchiveWriter, StoreError

ARCHIVE_NAME = "archive.szt"
#: v2 = single archive per step; v3 adds mesh-sharded entries
#: (kind "sz-sharded" + shard_manifest.json, docs/distributed.md).
MANIFEST_VERSION = 3
_STORE_MANIFEST_VERSION = 2     # first version with .szt-archived sz entries


class CheckpointIntegrityError(RuntimeError):
    """A checkpoint entry is missing, truncated, or fails its checksum."""


def _entry_spec(fname: str, shape: tuple, mesh):
    """Partition spec of a flat checkpoint entry under the sharding rules.

    Entry names are dot-joined tree paths ("params.layers.0.attn.wq");
    the rules in ``runtime/sharding.py`` match "/"-joined substrings, so
    the path is translated before lookup.  Optimizer entries reuse their
    parameter's rules the same way ``opt_state_shardings`` does: the
    leading m/v element and any quantized-leaf suffix are stripped.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime.sharding import param_spec

    tname, _, key = fname.partition(".")
    path = key.replace(".", "/")
    if tname == "opt":
        if path.endswith("step"):
            return P()
        path = path.split("/", 1)[1] if "/" in path else path
        for suffix in ("/q", "/scale", "/f"):
            if path.endswith(suffix):
                path = path[: -len(suffix)]
                break
    return param_spec(path, shape, mesh)


def _write_json_atomic(path: str, obj) -> None:
    """Durable atomic JSON write: temp file + fsync + rename + dir fsync.

    A crash at any point leaves either the old file or the new one, never
    a torn half-write -- and the rename is not published before the bytes
    are durable, so power loss cannot surface an empty manifest either.
    """
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _flatten(tree):
    flat = {}

    def rec(prefix, t):
        if isinstance(t, dict):
            for k, v in t.items():
                rec(f"{prefix}.{k}" if prefix else str(k), v)
        else:
            flat[prefix] = t

    rec("", tree)
    return flat


def _unflatten(flat):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _file_crc32(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(1 << 20)
            if not buf:
                return crc & 0xFFFFFFFF
            crc = zlib.crc32(buf, crc)


class _CrcTee:
    """File-object wrapper that CRCs bytes as they are written, so the raw
    save path never re-reads what it just wrote."""

    def __init__(self, f):
        self._f = f
        self.crc = 0

    def write(self, buf):
        self.crc = zlib.crc32(buf, self.crc) & 0xFFFFFFFF
        return self._f.write(buf)

    def __getattr__(self, name):
        return getattr(self._f, name)


class CheckpointManager:
    """Checkpoints over the store, with one ``Codec`` as the whole policy.

    ``codec=None`` saves raw shards only.  With a codec, float32 shards of
    at least ``compress_min_size`` elements compress under the codec's
    eb/mode into the step archive, and restores decode with the codec's
    method/backend -- re-restores hit its plan cache (phase 4 only).
    """

    def __init__(self, directory: str, codec: "Codec | None" = None,
                 compress_min_size: int = 65536, asynchronous: bool = False):
        self.dir = directory
        self.codec = codec
        self.min_size = compress_min_size
        os.makedirs(directory, exist_ok=True)
        self._pool = futures.ThreadPoolExecutor(1) if asynchronous else None
        self._pending = None

    @property
    def _read_codec(self) -> Codec:
        """Codec for the restore path: a raw-only manager can still read a
        compressed checkpoint through the default codec."""
        return self.codec if self.codec is not None else default_codec()

    # -- write --------------------------------------------------------------

    def save(self, step: int, params, opt_state=None, extra: dict | None = None,
             *, mesh=None, shardings=None, opt_shardings=None,
             shard_count: "int | None" = None):
        """Save a step.  With ``mesh=`` (or explicit ``shardings=`` /
        ``opt_shardings=`` pytrees of ``NamedSharding``), compressible
        entries write the mesh-sharded layout (docs/distributed.md):
        partitioned by their ``runtime/sharding.py`` specs into
        ``shard_count`` per-host ``.szt`` shards (default: one per
        process) that ``restore(mesh=...)`` decodes in parallel, directly
        into the target shardings."""
        if self._pool is not None:
            self.wait()
            params = jax.tree.map(np.asarray, params)  # snapshot now
            opt_state = jax.tree.map(np.asarray, opt_state) if opt_state else None
            self._pending = self._pool.submit(
                self._save_sync, step, params, opt_state, extra, mesh,
                shardings, opt_shardings, shard_count)
            return
        self._save_sync(step, params, opt_state, extra, mesh, shardings,
                        opt_shardings, shard_count)

    def _save_sync(self, step, params, opt_state, extra, mesh=None,
                   shardings=None, opt_shardings=None, shard_count=None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"version": _STORE_MANIFEST_VERSION, "step": step,
                    "entries": {}, "extra": extra or {}}
        trees = {"params": params}
        if opt_state is not None:
            trees["opt"] = opt_state
        sharded = (mesh is not None or shardings is not None
                   or opt_shardings is not None) and self.codec is not None
        spec_trees = {"params": shardings, "opt": opt_shardings}
        writer = sw = None
        try:
            for tname, tree in trees.items():
                flat = {key: np.asarray(leaf)
                        for key, leaf in _flatten(tree).items()}
                flat_specs = (_flatten(spec_trees[tname])
                              if spec_trees[tname] is not None else None)
                if self.codec is not None and not sharded:
                    # Tree-level compression: every float32 shard above the
                    # size floor becomes a Compressed leaf in one codec call.
                    flat = self.codec.compress_tree(flat,
                                                    min_size=self.min_size)
                for key, leaf in flat.items():
                    fname = f"{tname}.{key}"
                    if (sharded and isinstance(leaf, np.ndarray)
                            and leaf.dtype == np.float32
                            and leaf.size >= self.min_size):
                        if sw is None:
                            sw = ShardedWriter(
                                tmp, mesh, codec=self.codec,
                                n_shards=shard_count
                                or max(1, jax.process_count()))
                        spec = (flat_specs.get(key)
                                if flat_specs is not None
                                else _entry_spec(fname, leaf.shape, mesh))
                        sw.add(fname, leaf, spec)
                        manifest["entries"][fname] = {
                            "kind": "sz-sharded",
                            "shape": [int(s) for s in leaf.shape],
                            "dtype": str(leaf.dtype)}
                    elif isinstance(leaf, Compressed):
                        if writer is None:
                            writer = ArchiveWriter(
                                os.path.join(tmp, ARCHIVE_NAME),
                                codec=self.codec)
                        writer.add(fname, leaf,
                                   orig_dtype=str(np.dtype(leaf.dtype)))
                        # shape/dtype recorded so a zero_fill restore can
                        # size the substitute even when the archive is gone.
                        manifest["entries"][fname] = {
                            "kind": "sz",
                            "shape": [int(s) for s in leaf.shape],
                            "dtype": str(np.dtype(leaf.dtype))}
                    else:
                        path = os.path.join(tmp, fname + ".npy")
                        with open(path, "wb") as f:
                            tee = _CrcTee(f)
                            np.save(tee, leaf, allow_pickle=False)
                        manifest["entries"][fname] = {
                            "kind": "raw", "dtype": str(leaf.dtype),
                            "shape": [int(s) for s in leaf.shape],
                            "checksum": tee.crc}
        except BaseException:
            if writer is not None:
                writer.abort()
            if sw is not None:
                sw.abort()
            raise
        if writer is not None:
            for fname, crc in writer.checksums().items():
                manifest["entries"][fname]["checksum"] = crc
            writer.close()
        if sw is not None:
            sw.close()
            manifest["version"] = MANIFEST_VERSION
            manifest["n_shards"] = sw.n_shards
        _write_json_atomic(os.path.join(tmp, "manifest.json"), manifest)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- read ---------------------------------------------------------------

    def _steps(self) -> list:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self):
        steps = self._steps()
        return max(steps) if steps else None

    def _load_manifest(self, d: str, step: int) -> dict:
        """Parse a step's manifest; every failure mode -- missing, torn
        half-write, valid-JSON-wrong-shape -- is the named
        ``CheckpointIntegrityError``, never a raw parse error."""
        mpath = os.path.join(d, "manifest.json")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except FileNotFoundError as e:
            raise CheckpointIntegrityError(
                f"step {step}: manifest.json is missing") from e
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            raise CheckpointIntegrityError(
                f"step {step}: manifest.json is torn or unreadable: "
                f"{e}") from e
        entries = manifest.get("entries") if isinstance(manifest, dict) \
            else None
        if not isinstance(entries, dict) or not all(
                isinstance(m, dict) and "kind" in m
                for m in entries.values()):
            raise CheckpointIntegrityError(
                f"step {step}: manifest.json is structurally invalid")
        version = manifest.get("version", 1)
        if version > MANIFEST_VERSION:
            raise CheckpointIntegrityError(
                f"step {step}: manifest version {version} is newer than this "
                f"reader (supports <= {MANIFEST_VERSION})")
        if version < _STORE_MANIFEST_VERSION and any(
                m["kind"] == "sz" for m in entries.values()):
            raise CheckpointIntegrityError(
                f"step {step}: checkpoint uses the pre-store manifest "
                f"version {version} (loose .szblob.npz shards); re-save it "
                f"with this manager's writer -- it is not corrupt")
        return manifest

    def _restore_archive(self, d: str, step: int, manifest, pol,
                         quarantined: dict) -> dict:
        """Decode every compressed entry of a step's archive (integrity-
        checked, plan-cached, I/O overlapped with decode).

        Under a non-raise policy, failures quarantine entries (recorded in
        ``quarantined`` as name -> reason) instead of aborting: a corrupt
        chunk loses that entry, a corrupt/missing archive loses all of
        them, and everything else restores.
        """
        sz_entries = {fname: meta for fname, meta in
                      manifest["entries"].items() if meta["kind"] == "sz"}
        if not sz_entries:
            return {}
        apath = os.path.join(d, ARCHIVE_NAME)

        def lose_all(reason: str) -> dict:
            if pol.on_error == "raise":
                raise CheckpointIntegrityError(f"step {step}: {reason}")
            for fname in sz_entries:
                quarantined[fname] = reason
            return {}

        if not os.path.exists(apath):
            return lose_all(f"manifest lists {len(sz_entries)} compressed "
                            f"entries but {ARCHIVE_NAME} is missing")
        try:
            ar = Archive(apath, codec=self._read_codec)
        except (StoreError, OSError) as e:
            return lose_all(f"{ARCHIVE_NAME} is corrupt or truncated: {e}")
        with ar:
            want = []
            for fname, meta in sz_entries.items():
                if fname not in ar:
                    reason = f"entry missing from {ARCHIVE_NAME}"
                elif (meta.get("checksum") is not None
                        and ar.chunk(fname).crc32 != meta["checksum"]):
                    reason = (f"entry checksum in manifest.json disagrees "
                              f"with {ARCHIVE_NAME}")
                else:
                    want.append(fname)
                    continue
                if pol.on_error == "raise":
                    raise CheckpointIntegrityError(
                        f"step {step}: {fname!r}: {reason}")
                quarantined[fname] = reason

            def on_error(name, exc):
                quarantined[name] = f"{type(exc).__name__}: {exc}"

            try:
                if pol.on_error == "raise":
                    return ar.read_all(want, policy="raise")
                # Salvage: skip failed chunks here; restore() substitutes
                # zeros for quarantined entries under "zero_fill".
                return ar.read_all(want, policy="skip", on_error=on_error)
            except (StoreError, hp.DecodeGuardError) as e:
                raise CheckpointIntegrityError(
                    f"step {step}: {ARCHIVE_NAME} is corrupt or truncated: "
                    f"{e}") from e

    def _restore_sharded(self, d: str, step: int, manifest, pol,
                         quarantined: dict, targets: dict) -> dict:
        """Decode every mesh-sharded entry of a step (per-shard parallel
        decode, landing in ``targets`` shardings; docs/distributed.md).

        Mirrors ``_restore_archive``'s salvage contract: under a non-raise
        policy a corrupt/missing shard quarantines only the entries with
        tiles in it (the reason names the shard file), and a lost shard
        manifest loses all sharded entries.
        """
        entries = {f: m for f, m in manifest["entries"].items()
                   if m["kind"] == "sz-sharded"}
        if not entries:
            return {}

        def lose_all(reason: str) -> dict:
            if pol.on_error == "raise":
                raise CheckpointIntegrityError(f"step {step}: {reason}")
            for fname in entries:
                quarantined[fname] = reason
            return {}

        try:
            restorer = ShardedRestorer(d, codec=self._read_codec)
        except StoreError as e:
            return lose_all(f"sharded layout is unreadable: {e}")

        missing = [f for f in entries if f not in restorer.entries]
        if missing:
            return lose_all(f"{len(missing)} sharded entries (e.g. "
                            f"{missing[0]!r}) are missing from the shard "
                            f"manifest")

        def on_error(name, exc):
            quarantined[name] = f"{type(exc).__name__}: {exc}"

        try:
            if pol.on_error == "raise":
                return restorer.restore(targets, names=list(entries),
                                        policy="raise")
            # Salvage: skip failed entries here; restore() substitutes
            # zeros for quarantined entries under "zero_fill".
            return restorer.restore(targets, names=list(entries),
                                    policy="skip", on_error=on_error)
        except (StoreError, hp.DecodeGuardError) as e:
            raise CheckpointIntegrityError(f"step {step}: {e}") from e

    def _restore_raw(self, d: str, step: int, fname: str, meta):
        path = os.path.join(d, fname + ".npy")
        if not os.path.exists(path):
            raise CheckpointIntegrityError(
                f"step {step}: raw shard {fname!r} is missing")
        want = meta.get("checksum")
        if want is not None and _file_crc32(path) != want:
            raise CheckpointIntegrityError(
                f"step {step}: raw shard {fname!r} failed its checksum "
                f"(corrupt or truncated file)")
        try:
            return jnp.asarray(np.load(path, allow_pickle=False))
        except (ValueError, OSError, EOFError) as e:
            raise CheckpointIntegrityError(
                f"step {step}: raw shard {fname!r} is unreadable: {e}") from e

    @staticmethod
    def _zero_fill(meta: dict, pol):
        """Zeros of an entry's recorded shape/dtype, or None when the
        policy isn't ``zero_fill`` / the manifest predates shape records."""
        if pol.on_error != "zero_fill":
            return None
        shape, dtype = meta.get("shape"), meta.get("dtype")
        if shape is None or dtype is None:
            return None
        return jnp.zeros(tuple(int(s) for s in shape), jnp.dtype(dtype))

    def restore(self, step: int | None = None, policy=None, *, mesh=None,
                shardings=None, opt_shardings=None):
        """Restore a step (default: newest).

        ``mesh=`` (or explicit ``shardings=`` / ``opt_shardings=`` pytrees)
        gives every entry a target ``NamedSharding``: mesh-sharded entries
        decode per shard in parallel and are assembled *directly* into
        their target sharding (no gather-then-reshard hop -- the restore
        mesh need not match the write mesh), and raw/archived entries are
        placed with ``jax.device_put``.  Without either, every entry
        restores as a full array on the default device, whatever layout it
        was written in.

        ``policy`` (a string or ``RecoveryPolicy``; default: the codec's
        ``recovery`` config, i.e. ``"raise"``) selects salvage behaviour on
        corruption:

        * ``"raise"`` -- any integrity failure raises the named
          ``CheckpointIntegrityError`` (the historical behaviour).
        * ``"skip"`` -- intact entries restore; failing ones are omitted
          and reported in the result's ``"quarantined"`` dict
          (name -> reason).  When the *newest* step's manifest is torn and
          no explicit ``step`` was requested, restore falls back to the
          newest intact step (skipped steps listed in ``"fallback_from"``).
        * ``"zero_fill"`` -- like ``"skip"``, but quarantined entries are
          replaced by zeros of their recorded shape/dtype so the restored
          tree keeps its structure.
        """
        pol = self._read_codec.recovery_policy(policy)
        fallback_from: list = []
        if step is None:
            manifest = None
            for s in reversed(self._steps()):
                d = os.path.join(self.dir, f"step_{s:08d}")
                try:
                    manifest = self._load_manifest(d, s)
                    step = s
                    break
                except CheckpointIntegrityError as e:
                    if pol.on_error == "raise":
                        raise
                    fallback_from.append({"step": s, "reason": str(e)})
            if manifest is None:
                return None
        else:
            d = os.path.join(self.dir, f"step_{step:08d}")
            manifest = self._load_manifest(d, step)
        targets: dict = {}
        for tname, stree in (("params", shardings), ("opt", opt_shardings)):
            if stree is not None:
                for key, s in _flatten(stree).items():
                    targets[f"{tname}.{key}"] = s
        if mesh is not None:
            from jax.sharding import NamedSharding
            for fname, meta in manifest["entries"].items():
                if fname not in targets and meta.get("shape") is not None:
                    targets[fname] = NamedSharding(
                        mesh, _entry_spec(fname, tuple(meta["shape"]), mesh))

        trees: dict = {"params": {}, "opt": {}}
        quarantined: dict = {}
        sz_restored = self._restore_archive(d, step, manifest, pol,
                                            quarantined)
        sharded_restored = self._restore_sharded(d, step, manifest, pol,
                                                 quarantined, targets)
        for fname, meta in manifest["entries"].items():
            tname, _, key = fname.partition(".")
            if not key:
                if pol.on_error == "raise":
                    raise CheckpointIntegrityError(
                        f"step {step}: malformed entry name {fname!r}")
                quarantined[fname] = "malformed entry name"
                continue
            placed = False
            if meta["kind"] == "sz-sharded":
                arr = sharded_restored.get(fname)
                placed = arr is not None  # restorer lands in the sharding
                if arr is None:          # quarantined by _restore_sharded
                    arr = self._zero_fill(meta, pol)
                    if arr is None:
                        continue
            elif meta["kind"] == "sz":
                arr = sz_restored.get(fname)
                if arr is None:          # quarantined by _restore_archive
                    arr = self._zero_fill(meta, pol)
                    if arr is None:
                        continue
            else:
                try:
                    arr = self._restore_raw(d, step, fname, meta)
                except CheckpointIntegrityError as e:
                    if pol.on_error == "raise":
                        raise
                    quarantined[fname] = str(e)
                    arr = self._zero_fill(meta, pol)
                    if arr is None:
                        continue
            if not placed:
                tgt = targets.get(fname)
                if tgt is not None:
                    arr = jax.device_put(arr, tgt)
            trees.setdefault(tname, {})[key] = arr
        params = _unflatten(trees["params"])
        opt = _unflatten(trees["opt"]) if trees.get("opt") else None
        return {"step": step, "params": params, "opt": opt,
                "extra": manifest.get("extra", {}),
                "quarantined": quarantined, "fallback_from": fallback_from}
