"""Sharded checkpointing with optional SZ-compressed float shards.

Layout:  <dir>/step_<N>/{manifest.json, <flat-key>.npy | <flat-key>.szblob}
Writes are atomic (tmp dir + rename) so a preempted save can never corrupt
the restore path -- the fault-tolerance tests kill a training process mid-run
and restart from ``latest_step``.

Compressed shards use the paper's pipeline (core.sz): error-bounded Lorenzo +
Huffman with the optimized parallel decoder on restore.  Weights tolerate a
small bounded perturbation; optimizer moments are stored raw by default
(configurable).  This is the paper's "compressed snapshot / restart file"
use case made first-class.
"""

from __future__ import annotations

import concurrent.futures as futures
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as sz


def _flatten(tree):
    flat = {}

    def rec(prefix, t):
        if isinstance(t, dict):
            for k, v in t.items():
                rec(f"{prefix}.{k}" if prefix else str(k), v)
        else:
            flat[prefix] = t

    rec("", tree)
    return flat


def _unflatten(flat):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _save_blob(path, arr, eb):
    c = sz.compress(np.asarray(arr, np.float32), eb=eb, mode="rel")
    np.savez(
        path,
        units=np.asarray(c.stream.units),
        gaps=np.asarray(c.stream.gaps),
        counts=np.asarray(c.stream.counts),
        seq_counts=np.asarray(c.stream.seq_counts),
        total_bits=int(c.stream.total_bits),
        n_symbols=int(c.stream.n_symbols),
        subseqs_per_seq=c.stream.subseqs_per_seq,
        enc_code=c.codebook.enc_code, enc_len=c.codebook.enc_len,
        dec_sym=c.codebook.dec_sym, dec_len=c.codebook.dec_len,
        max_len=c.codebook.max_len,
        outlier_pos=np.asarray(c.outlier_pos),
        outlier_val=np.asarray(c.outlier_val),
        shape=np.array(c.shape), eb=c.eb, radius=c.radius,
        rel_range=c.rel_range, max_abs=c.max_abs,
        orig_dtype=str(arr.dtype),
    )


def _read_blob(path):
    """Parse a .szblob.npz into (Compressed, original dtype string)."""
    z = np.load(path)
    from repro.core.huffman.codebook import Codebook
    from repro.core.huffman.encode import EncodedStream
    from repro.core.sz.compressor import Compressed

    stream = EncodedStream(
        units=jnp.asarray(z["units"]), gaps=jnp.asarray(z["gaps"]),
        counts=jnp.asarray(z["counts"]),
        seq_counts=jnp.asarray(z["seq_counts"]),
        total_bits=jnp.asarray(z["total_bits"]),
        n_symbols=jnp.asarray(z["n_symbols"]),
        subseqs_per_seq=int(z["subseqs_per_seq"]))
    book = Codebook(
        n_symbols=len(z["enc_code"]), max_len=int(z["max_len"]),
        enc_code=z["enc_code"], enc_len=z["enc_len"],
        dec_sym=z["dec_sym"], dec_len=z["dec_len"])
    c = Compressed(
        stream=stream, codebook=book,
        outlier_pos=jnp.asarray(z["outlier_pos"]),
        outlier_val=jnp.asarray(z["outlier_val"]),
        shape=tuple(int(s) for s in z["shape"]),
        dtype=np.dtype(str(z["orig_dtype"])) if str(z["orig_dtype"]) != "bfloat16"
        else np.dtype(np.float32),
        eb=float(z["eb"]), radius=int(z["radius"]),
        rel_range=float(z["rel_range"]), max_abs=float(z["max_abs"]))
    return c, str(z["orig_dtype"])


def _load_blob(path, method="gap"):
    c, orig_dtype = _read_blob(path)
    x = sz.decompress(c, method=method)
    return jnp.asarray(x, jnp.dtype(orig_dtype))


def _load_blobs_batched(paths, method="gap"):
    """Restore many compressed shards with class-batched decode.

    All shards decode through ``sz.decompress_batch`` -- one Huffman
    decode-write dispatch per CR class across the whole checkpoint instead
    of one tuned decode per shard.
    """
    blobs = [_read_blob(p) for p in paths]
    xs = sz.decompress_batch([c for c, _ in blobs], method=method)
    return [jnp.asarray(x, jnp.dtype(dt))
            for x, (_, dt) in zip(xs, blobs)]


class CheckpointManager:
    def __init__(self, directory: str, compress_eb: float | None = None,
                 compress_min_size: int = 65536, asynchronous: bool = False):
        self.dir = directory
        self.eb = compress_eb
        self.min_size = compress_min_size
        os.makedirs(directory, exist_ok=True)
        self._pool = futures.ThreadPoolExecutor(1) if asynchronous else None
        self._pending = None

    # -- write --------------------------------------------------------------

    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        if self._pool is not None:
            self.wait()
            params = jax.tree.map(np.asarray, params)  # snapshot now
            opt_state = jax.tree.map(np.asarray, opt_state) if opt_state else None
            self._pending = self._pool.submit(
                self._save_sync, step, params, opt_state, extra)
            return
        self._save_sync(step, params, opt_state, extra)

    def _save_sync(self, step, params, opt_state, extra):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "entries": {}, "extra": extra or {}}
        trees = {"params": params}
        if opt_state is not None:
            trees["opt"] = opt_state
        for tname, tree in trees.items():
            for key, leaf in _flatten(tree).items():
                arr = np.asarray(leaf)
                fname = f"{tname}.{key}"
                compressible = (self.eb is not None
                                and arr.dtype in (np.float32,)
                                and arr.size >= self.min_size)
                if compressible:
                    _save_blob(os.path.join(tmp, fname + ".szblob.npz"),
                               arr, self.eb)
                    manifest["entries"][fname] = {"kind": "sz"}
                else:
                    np.save(os.path.join(tmp, fname + ".npy"),
                            arr, allow_pickle=False)
                    manifest["entries"][fname] = {
                        "kind": "raw", "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- read ---------------------------------------------------------------

    def latest_step(self):
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        trees: dict = {"params": {}, "opt": {}}
        sz_names = [fname for fname, meta in manifest["entries"].items()
                    if meta["kind"] == "sz"]
        sz_arrays = _load_blobs_batched(
            [os.path.join(d, fname + ".szblob.npz") for fname in sz_names])
        sz_restored = dict(zip(sz_names, sz_arrays))
        for fname, meta in manifest["entries"].items():
            tname, key = fname.split(".", 1)
            if meta["kind"] == "sz":
                arr = sz_restored[fname]
            else:
                arr = jnp.asarray(
                    np.load(os.path.join(d, fname + ".npy")))
            trees.setdefault(tname, {})[key] = arr
        params = _unflatten(trees["params"])
        opt = _unflatten(trees["opt"]) if trees.get("opt") else None
        return {"step": step, "params": params, "opt": opt,
                "extra": manifest.get("extra", {})}
