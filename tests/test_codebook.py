"""Codebook construction: package-merge optimality, canonical prefix codes."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.huffman import codebook as cb


def entropy_bits(freq):
    p = freq[freq > 0] / freq.sum()
    return float(-(p * np.log2(p)).sum())


class TestPackageMerge:
    def test_two_symbols(self):
        lengths = cb.code_lengths_package_merge(np.array([5, 3]), 4)
        assert list(lengths) == [1, 1]

    def test_single_symbol(self):
        lengths = cb.code_lengths_package_merge(np.array([0, 7, 0]), 4)
        assert list(lengths) == [0, 1, 0]

    def test_kraft_equality(self, rng):
        freq = rng.integers(1, 1000, size=64)
        lengths = cb.code_lengths_package_merge(freq, 12)
        kraft = np.sum(0.5 ** lengths[lengths > 0].astype(float))
        assert kraft <= 1.0 + 1e-12
        # optimal codes saturate Kraft
        assert kraft == pytest.approx(1.0)

    def test_respects_max_len(self, rng):
        # extreme skew would want very long tails without limiting
        freq = (2 ** np.arange(20))[::-1]
        for L in (6, 8, 12):
            lengths = cb.code_lengths_package_merge(freq, L)
            assert lengths.max() <= L

    def test_near_entropy(self, rng):
        freq = np.bincount(np.clip(rng.zipf(1.5, 20000), 0, 511),
                           minlength=512)
        lengths = cb.code_lengths_package_merge(freq, 12)
        avg = (freq * lengths).sum() / freq.sum()
        h = entropy_bits(freq)
        assert h <= avg <= h + 1.05  # Huffman redundancy bound (~1 bit)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(0, 10000), min_size=2, max_size=128),
           st.sampled_from([8, 10, 12]))
    def test_property_valid_code(self, freqs, max_len):
        freq = np.array(freqs)
        if (freq > 0).sum() == 0 or (freq > 0).sum() > 2 ** max_len:
            return
        lengths = cb.code_lengths_package_merge(freq, max_len)
        used = lengths[freq > 0]
        if (freq > 0).sum() >= 2:
            assert (used >= 1).all()
        assert lengths.max() <= max_len
        assert np.sum(0.5 ** used.astype(float)) <= 1.0 + 1e-12


class TestCanonical:
    def test_prefix_free(self, rng):
        freq = rng.integers(0, 500, size=256)
        freq[0] = 1  # ensure at least one
        book = cb.build_codebook(freq, max_len=12)
        codes = []
        for s in np.nonzero(book.enc_len > 0)[0]:
            bits = format(book.enc_code[s], f"0{book.enc_len[s]}b")
            codes.append(bits)
        codes.sort()
        for a, b in zip(codes, codes[1:]):
            assert not b.startswith(a), (a, b)

    def test_lut_decodes_every_code(self, rng):
        freq = rng.integers(1, 100, size=64)
        book = cb.build_codebook(freq, max_len=10)
        for s in range(64):
            length = int(book.enc_len[s])
            window = int(book.enc_code[s]) << (book.max_len - length)
            assert book.dec_sym[window] == s
            assert book.dec_len[window] == length

    def test_min_starts_bound(self):
        freq = np.ones(16, np.int64)
        book = cb.build_codebook(freq, max_len=12)
        assert book.min_starts_per_subseq(128) >= 9
