"""Compressed tensor store: format round trip, integrity, cache, paging."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api as sz
from repro.core.huffman import pipeline as hp
from repro.data.pipeline import smooth_field
from repro.store import (
    Archive,
    ArchiveWriter,
    KVPager,
    PlanCache,
    StoreCorruptError,
    StoreError,
    StoreVersionError,
    write_archive,
)
from repro.store import format as F


def _kv_codec(eb=1e-3):
    """A pager codec with its own plan cache (isolated from the default)."""
    from repro.core import Codec, CodecConfig
    return Codec(CodecConfig(eb=eb), plan_cache=PlanCache())


def _entries(n=4, seed=0):
    out = []
    for i in range(n):
        x = np.asarray(smooth_field((48, 40 + 9 * i), seed=seed + i),
                       np.float32)
        out.append((f"t{i}", sz.compress(x, eb=1e-3), "float32"))
    return out


@pytest.fixture()
def archive_path(tmp_path):
    path = str(tmp_path / "a.szt")
    write_archive(path, _entries())
    return path


class TestRoundTrip:
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    def test_bit_exact_vs_decompress(self, archive_path, backend):
        entries = _entries()
        with Archive(archive_path, plan_cache=PlanCache()) as ar:
            out = ar.read_all(backend=backend)
        for name, c, _ in entries:
            ref = np.asarray(sz.decompress(c, strategy="tuned"))
            assert np.asarray(out[name]).tobytes() == ref.tobytes(), name

    def test_prefetch_matches_serial(self, archive_path):
        with Archive(archive_path, plan_cache=PlanCache()) as ar:
            a = ar.read_all(group_chunks=1, prefetch=True)
            b = ar.read_all(group_chunks=1, prefetch=False)
        for n in a:
            assert np.asarray(a[n]).tobytes() == np.asarray(b[n]).tobytes()

    def test_orig_dtype_cast_stays_on_device(self, tmp_path):
        x = np.asarray(smooth_field((64, 32), seed=1), np.float32)
        path = str(tmp_path / "bf16.szt")
        write_archive(path, [("w", sz.compress(x, eb=1e-3), "bfloat16")])
        with Archive(path, plan_cache=PlanCache()) as ar:
            out = ar.read_tensor("w")
        assert out.dtype == jnp.bfloat16
        assert isinstance(out, jax.Array)

    def test_duplicate_names_rejected(self, tmp_path):
        (name, c, dt), *_ = _entries(1)
        with pytest.raises(StoreError):
            with ArchiveWriter(str(tmp_path / "d.szt")) as w:
                w.add(name, c, dt)
                w.add(name, c, dt)


class TestIntegrity:
    def test_truncated_file(self, archive_path):
        size = os.path.getsize(archive_path)
        with open(archive_path, "r+b") as f:
            f.truncate(size - 32)
        with pytest.raises(StoreCorruptError):
            Archive(archive_path, plan_cache=PlanCache())

    def test_truncated_to_partial_header(self, archive_path):
        with open(archive_path, "r+b") as f:
            f.truncate(F.HEADER_SIZE // 2)
        with pytest.raises(StoreCorruptError):
            Archive(archive_path, plan_cache=PlanCache())

    def test_version_mismatch(self, archive_path):
        with open(archive_path, "r+b") as f:
            f.seek(8)  # version field follows the 8-byte magic
            f.write((F.FORMAT_VERSION + 1).to_bytes(4, "little"))
        with pytest.raises(StoreVersionError):
            Archive(archive_path, plan_cache=PlanCache())

    def test_bad_magic(self, archive_path):
        with open(archive_path, "r+b") as f:
            f.write(b"NOTASTOR")
        with pytest.raises(StoreError):
            Archive(archive_path, plan_cache=PlanCache())

    def test_corrupt_chunk_payload(self, archive_path):
        with Archive(archive_path, plan_cache=PlanCache()) as ar:
            rec = ar.chunk("t2")
        pos = rec.units.offset + rec.units.length // 2
        with open(archive_path, "r+b") as f:
            f.seek(pos)
            flipped = f.read(1)[0] ^ 0xFF
            f.seek(pos)
            f.write(bytes([flipped]))
        with Archive(archive_path, plan_cache=PlanCache()) as ar:
            with pytest.raises(StoreCorruptError, match="t2"):
                ar.read_chunk("t2")
            # other chunks still read fine
            ar.read_chunk("t0")

    def test_corrupt_codebook_payload(self, archive_path):
        with Archive(archive_path, plan_cache=PlanCache()) as ar:
            cb_rec = ar._cb_by_digest[ar.chunk("t0").codebook]
        with open(archive_path, "r+b") as f:
            f.seek(cb_rec.enc_code.offset)
            f.write(b"\xff\xff\xff\xff")
        with Archive(archive_path, plan_cache=PlanCache()) as ar:
            with pytest.raises(StoreCorruptError, match="codebook"):
                ar.read_chunk("t0")

    def test_no_tmp_left_behind(self, archive_path):
        d = os.path.dirname(archive_path)
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


class TestCodebookDedup:
    def test_identical_histograms_share_one_table(self, tmp_path):
        x = np.asarray(smooth_field((48, 48), seed=3), np.float32)
        y = np.asarray(smooth_field((48, 48), seed=4), np.float32)
        path = str(tmp_path / "dedup.szt")
        write_archive(path, [
            ("a", sz.compress(x, eb=1e-3), "float32"),
            ("b", sz.compress(x, eb=1e-3), "float32"),  # same histogram
            ("c", sz.compress(y, eb=1e-3), "float32"),  # different
        ])
        with Archive(path, plan_cache=PlanCache()) as ar:
            assert len(ar) == 3
            assert ar.n_codebooks == 2
            assert ar.chunk("a").codebook == ar.chunk("b").codebook
            assert ar.chunk("a").codebook != ar.chunk("c").codebook
            out = ar.read_all()
        for name in ("a", "b"):
            err = np.abs(np.asarray(out[name]) - x).max()
            assert err <= 1e-3 * (x.max() - x.min()) * 1.01 + 1e-7


class TestPlanCache:
    def test_second_open_rebuilds_zero_plans(self, archive_path):
        cache = PlanCache()
        be = hp.get_backend("ref")

        be.reset_stats()
        with Archive(archive_path, plan_cache=cache) as ar:
            first = ar.read_all()
        assert be.stats["plan_builds"] == len(first)

        be.reset_stats()
        with Archive(archive_path, plan_cache=cache) as ar:
            second = ar.read_all()
        assert be.stats["plan_builds"] == 0
        assert cache.stats["plan_hits"] == len(first)
        for n in first:
            assert np.asarray(first[n]).tobytes() == \
                np.asarray(second[n]).tobytes()

    def test_method_keys_are_distinct(self, archive_path):
        cache = PlanCache()
        be = hp.get_backend("ref")
        with Archive(archive_path, plan_cache=cache) as ar:
            ar.read_all(method="gap")
            be.reset_stats()
            ar.read_all(method="selfsync")
        assert be.stats["plan_builds"] == 4  # selfsync plans are separate

    def test_lru_bound(self, archive_path):
        cache = PlanCache(max_plans=2)
        with Archive(archive_path, plan_cache=cache) as ar:
            ar.read_all()
        assert len(cache) == 2


class TestPaging:
    def _cache(self, seed=0, s=32):
        k = jax.random.PRNGKey(seed)
        # smooth along the token axis so the blocks actually compress
        base = jnp.cumsum(jax.random.normal(k, (2, 2, s, 2, 8)) * 0.05,
                          axis=2)
        return {"k": base, "v": base + 0.5, "pos": jnp.arange(4)}

    def test_offload_zeroes_and_page_in_restores(self, tmp_path):
        cache = self._cache()
        orig = {n: np.asarray(a, np.float32) for n, a in cache.items()}
        pager = KVPager(str(tmp_path), codec=_kv_codec())
        cache, bid = pager.offload(cache, 0, 16)
        assert np.all(np.asarray(cache["k"])[:, :, :16] == 0)
        assert np.array_equal(np.asarray(cache["k"])[:, :, 16:],
                              orig["k"][:, :, 16:])
        assert np.array_equal(np.asarray(cache["pos"]), orig["pos"])
        cache = pager.page_in(cache, bid)
        for n in ("k", "v"):
            rng = orig[n].max() - orig[n].min()
            err = np.abs(np.asarray(cache[n], np.float32) - orig[n]).max()
            assert err <= 1e-3 * rng * 1.01 + 1e-7

    def test_repeat_page_in_hits_plan_cache(self, tmp_path):
        cache = self._cache(seed=1)
        pager = KVPager(str(tmp_path), codec=_kv_codec())
        cache, bid = pager.offload(cache, 0, 16)
        cache = pager.page_in(cache, bid)
        be = hp.get_backend("ref")
        be.reset_stats()
        pager.page_in(cache, bid)
        assert be.stats["plan_builds"] == 0
        assert pager.stats["pages_in"] == 2

    def test_drop_deletes_archive(self, tmp_path):
        cache = self._cache(seed=2)
        pager = KVPager(str(tmp_path), codec=_kv_codec())
        cache, bid = pager.offload(cache, 8, 24)
        path = pager.block_meta(bid)["path"]
        assert os.path.exists(path)
        pager.drop(bid)
        assert not os.path.exists(path)
        assert pager.resident_blocks == []

    def test_empty_range_rejected(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_kv_codec())
        with pytest.raises(ValueError):
            pager.offload(self._cache(), 8, 8)
