"""Checkpoint manager: roundtrip, compression, atomicity, integrity, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (ARCHIVE_NAME, CheckpointIntegrityError,
                                      CheckpointManager)
from repro.core import Codec, CodecConfig
from repro.data.pipeline import smooth_field


def _codec(eb=1e-3):
    return Codec(CodecConfig(eb=eb))


def small_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (128, 64)),
                   "b": jnp.zeros((64,))},
        "embed": jnp.asarray(smooth_field((512, 32), seed=seed)),
    }


class TestRoundtrip:
    def test_raw(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        params = small_tree()
        opt = {"m": jax.tree.map(jnp.zeros_like, params),
               "step": jnp.int32(7)}
        mgr.save(3, params, opt)
        r = mgr.restore()
        assert r["step"] == 3
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(r["params"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert int(np.asarray(r["opt"]["step"])) == 7

    def test_compressed_within_bound(self, tmp_path):
        eb = 1e-3
        mgr = CheckpointManager(str(tmp_path), codec=_codec(eb),
                                compress_min_size=1024)
        params = small_tree()
        mgr.save(0, params)
        r = mgr.restore()
        for key in ("embed",):
            a = np.asarray(params[key], np.float32)
            b = np.asarray(r["params"][key], np.float32)
            rng_ = a.max() - a.min()
            assert np.abs(a - b).max() <= eb * rng_ * 1.01 + 1e-6

    def test_latest_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.latest_step() is None
        params = small_tree()
        for s in (1, 5, 3):
            mgr.save(s, params)
        assert mgr.latest_step() == 5

    def test_atomic_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, small_tree())
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), asynchronous=True)
        mgr.save(2, small_tree())
        mgr.wait()
        assert mgr.restore()["step"] == 2

    def test_one_archive_per_step(self, tmp_path):
        """Compressed shards pack into a single store archive, not N files."""
        mgr = CheckpointManager(str(tmp_path), codec=_codec(),
                                compress_min_size=1024)
        mgr.save(0, small_tree())
        d = os.path.join(str(tmp_path), "step_00000000")
        files = sorted(os.listdir(d))
        assert ARCHIVE_NAME in files
        assert not [f for f in files if f.endswith(".szblob.npz")]
        from repro.store import Archive
        with Archive(os.path.join(d, ARCHIVE_NAME)) as ar:
            assert "params.embed" in ar


class TestIntegrity:
    def _save(self, tmp_path, **kw):
        mgr = CheckpointManager(str(tmp_path), codec=_codec(),
                                compress_min_size=1024, **kw)
        mgr.save(0, small_tree())
        return mgr, os.path.join(str(tmp_path), "step_00000000")

    def test_corrupt_archive_raises_clear_error(self, tmp_path):
        mgr, d = self._save(tmp_path)
        path = os.path.join(d, ARCHIVE_NAME)
        from repro.store import Archive
        with Archive(path) as ar:
            rec = ar.chunk("params.embed")
        with open(path, "r+b") as f:
            f.seek(rec.units.offset)
            flipped = f.read(1)[0] ^ 0xFF
            f.seek(rec.units.offset)
            f.write(bytes([flipped]))
        with pytest.raises(CheckpointIntegrityError):
            mgr.restore()

    def test_truncated_archive_raises_clear_error(self, tmp_path):
        mgr, d = self._save(tmp_path)
        path = os.path.join(d, ARCHIVE_NAME)
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) - 64)
        with pytest.raises(CheckpointIntegrityError):
            mgr.restore()

    def test_missing_archive_raises_clear_error(self, tmp_path):
        mgr, d = self._save(tmp_path)
        os.unlink(os.path.join(d, ARCHIVE_NAME))
        with pytest.raises(CheckpointIntegrityError, match="missing"):
            mgr.restore()

    def test_corrupt_raw_shard_raises_clear_error(self, tmp_path):
        mgr, d = self._save(tmp_path)
        path = os.path.join(d, "params.layers.b.npy")
        with open(path, "r+b") as f:
            f.seek(os.path.getsize(path) - 1)
            flipped = f.read(1)[0] ^ 0xFF
            f.seek(os.path.getsize(path) - 1)
            f.write(bytes([flipped]))
        with pytest.raises(CheckpointIntegrityError,
                           match="params.layers.b"):
            mgr.restore()

    def test_truncated_raw_shard_raises_clear_error(self, tmp_path):
        """A half-written .npy surfaces as an integrity error, not a numpy
        parse failure."""
        mgr, d = self._save(tmp_path)
        path = os.path.join(d, "params.layers.b.npy")
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) // 2)
        with pytest.raises(CheckpointIntegrityError):
            mgr.restore()

    def test_restore_uses_plan_cache_on_second_restore(self, tmp_path):
        from repro.core.huffman import pipeline as hp
        mgr, _ = self._save(tmp_path)
        be = hp.get_backend("ref")
        mgr.restore()
        be.reset_stats()
        mgr.restore()
        assert be.stats["plan_builds"] == 0


class TestResume:
    def test_training_resumes_identically(self, tmp_path):
        """checkpoint at step k, continue; vs uninterrupted -- identical."""
        from repro import configs
        from repro.models import steps as S, transformer as T
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = configs.get_config("qwen3-0.6b").reduced(n_layers=1)
        ocfg = adamw.AdamWConfig(lr=1e-3)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=2, seed=0))
        step_fn = jax.jit(S.make_train_step(cfg, ocfg))

        def run(params, opt, lo, hi):
            for s in range(lo, hi):
                params, opt, _ = step_fn(params, opt, data.batch_at(s))
            return params, opt

        p0 = T.init_model(jax.random.PRNGKey(0), cfg)
        o0 = adamw.init(p0, ocfg)

        # uninterrupted 6 steps
        pa, _ = run(p0, o0, 0, 6)

        # interrupted at 3 + restore + 3 more
        pb, ob = run(p0, o0, 0, 3)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, pb, ob)
        r = mgr.restore()
        pc, _ = run(r["params"], r["opt"], 3, 6)

        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-6, atol=1e-6)
