"""Checkpoint manager: roundtrip, compression, atomicity, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import smooth_field


def small_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (128, 64)),
                   "b": jnp.zeros((64,))},
        "embed": jnp.asarray(smooth_field((512, 32), seed=seed)),
    }


class TestRoundtrip:
    def test_raw(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        params = small_tree()
        opt = {"m": jax.tree.map(jnp.zeros_like, params),
               "step": jnp.int32(7)}
        mgr.save(3, params, opt)
        r = mgr.restore()
        assert r["step"] == 3
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(r["params"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert int(np.asarray(r["opt"]["step"])) == 7

    def test_compressed_within_bound(self, tmp_path):
        eb = 1e-3
        mgr = CheckpointManager(str(tmp_path), compress_eb=eb,
                                compress_min_size=1024)
        params = small_tree()
        mgr.save(0, params)
        r = mgr.restore()
        for key in ("embed",):
            a = np.asarray(params[key], np.float32)
            b = np.asarray(r["params"][key], np.float32)
            rng_ = a.max() - a.min()
            assert np.abs(a - b).max() <= eb * rng_ * 1.01 + 1e-6

    def test_latest_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.latest_step() is None
        params = small_tree()
        for s in (1, 5, 3):
            mgr.save(s, params)
        assert mgr.latest_step() == 5

    def test_atomic_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, small_tree())
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), asynchronous=True)
        mgr.save(2, small_tree())
        mgr.wait()
        assert mgr.restore()["step"] == 2


class TestResume:
    def test_training_resumes_identically(self, tmp_path):
        """checkpoint at step k, continue; vs uninterrupted -- identical."""
        from repro import configs
        from repro.models import steps as S, transformer as T
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = configs.get_config("qwen3-0.6b").reduced(n_layers=1)
        ocfg = adamw.AdamWConfig(lr=1e-3)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=2, seed=0))
        step_fn = jax.jit(S.make_train_step(cfg, ocfg))

        def run(params, opt, lo, hi):
            for s in range(lo, hi):
                params, opt, _ = step_fn(params, opt, data.batch_at(s))
            return params, opt

        p0 = T.init_model(jax.random.PRNGKey(0), cfg)
        o0 = adamw.init(p0, ocfg)

        # uninterrupted 6 steps
        pa, _ = run(p0, o0, 0, 6)

        # interrupted at 3 + restore + 3 more
        pb, ob = run(p0, o0, 0, 3)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, pb, ob)
        r = mgr.restore()
        pc, _ = run(r["params"], r["opt"], 3, 6)

        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-6, atol=1e-6)
