"""Codec sessions: config validation, parity with the free functions,
pytree round trips, cross-consumer plan-cache reuse, shim behavior."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.api import Codec, CodecConfig, PlanCache, default_codec
from repro.core.huffman import pipeline as hp
from repro.core.sz import compressor
from repro.data.pipeline import smooth_field


class TestCodecConfig:
    def test_defaults_are_the_paper_setting(self):
        cfg = CodecConfig()
        assert cfg.eb == 1e-3 and cfg.mode == "rel"
        assert cfg.method == "gap" and cfg.backend == "ref"

    @pytest.mark.parametrize("bad", [
        {"mode": "percentile"},
        {"method": "magic"},
        {"strategy": "huge_tiles"},
        {"backend": "cuda"},
        {"t_high": 0},
        {"eb": 0.0},
        {"eb": -1e-3},
        {"radius": 1},
        {"tile_syms": 0},
        {"plan_cache_size": -1},
    ])
    def test_invalid_config_rejected(self, bad):
        with pytest.raises(ValueError):
            CodecConfig(**bad)

    def test_invalid_names_list_valid_options(self):
        with pytest.raises(ValueError, match="tuned"):
            CodecConfig(strategy="nope")
        with pytest.raises(ValueError, match="ref"):
            CodecConfig(backend="nope")

    def test_frozen_and_hashable(self):
        cfg = CodecConfig()
        with pytest.raises(Exception):
            cfg.eb = 2e-3
        assert hash(cfg) == hash(CodecConfig())
        assert cfg.replace(eb=1e-4) != cfg

    def test_config_survives_replace_validation(self):
        with pytest.raises(ValueError):
            CodecConfig().replace(strategy="bogus")


class TestParityWithFreeFunctions:
    """Acceptance: Codec round trip is bit-exact with the engine functions
    over method x backend x strategy."""

    @pytest.mark.parametrize("method", ["gap", "selfsync", "naive_ref"])
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    @pytest.mark.parametrize("strategy", ["tuned", "tile", "padded"])
    def test_bit_exact(self, method, backend, strategy):
        x = smooth_field((48, 300), seed=11)
        codec = Codec(CodecConfig(method=method, backend=backend,
                                  strategy=strategy))
        c = codec.compress(x)
        got = np.asarray(codec.decompress(c))
        want = np.asarray(compressor.decompress(
            c, method=method, backend=backend, strategy=strategy))
        assert got.tobytes() == want.tobytes()
        assert np.abs(got - x).max() <= c.eb_effective

    def test_compress_matches_free_function(self):
        x = smooth_field((64, 64), seed=3)
        a = Codec().compress(x)
        b = api.compress(x)
        assert np.asarray(a.stream.units).tobytes() == \
            np.asarray(b.stream.units).tobytes()
        assert a.eb == b.eb

    def test_decompress_batch_matches_per_tensor(self):
        codec = Codec()
        cs = [codec.compress(smooth_field((30, 40 + 7 * i), seed=i))
              for i in range(3)]
        outs = codec.decompress_batch(cs)
        for c, out in zip(cs, outs):
            ref = np.asarray(codec.decompress(c))
            assert np.asarray(out).tobytes() == ref.tobytes()


class TestTreeRoundTrip:
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    def test_nested_pytree(self, backend):
        tree = {
            "layers": {"w": smooth_field((64, 48), seed=0),
                       "b": smooth_field((256,), seed=1)},
            "stack": [smooth_field((32, 32), seed=2),
                      np.arange(5, dtype=np.int32)],
            "step": 7,
        }
        codec = Codec(CodecConfig(backend=backend))
        ctree = codec.compress_tree(tree)
        assert isinstance(ctree["layers"]["w"], compressor.Compressed)
        assert ctree["stack"][1].dtype == np.int32     # passthrough
        assert ctree["step"] == 7
        back = codec.decompress_tree(ctree)
        for path in (("layers", "w"), ("layers", "b")):
            a = tree[path[0]][path[1]]
            b = np.asarray(back[path[0]][path[1]])
            c = ctree[path[0]][path[1]]
            assert b.shape == a.shape
            assert np.abs(a - b).max() <= c.eb_effective
        assert np.array_equal(np.asarray(back["stack"][1]),
                              tree["stack"][1])

    def test_min_size_floor(self):
        codec = Codec()
        tree = {"big": smooth_field((128, 128), seed=4),
                "tiny": np.ones((4,), np.float32)}
        ctree = codec.compress_tree(tree, min_size=1024)
        assert isinstance(ctree["big"], compressor.Compressed)
        assert isinstance(ctree["tiny"], np.ndarray)

    def test_batched_dispatch_across_tree(self):
        """The whole tree decodes in one class-batched call: dispatch count
        is bounded by CR classes, not leaf count."""
        codec = Codec()
        tree = {f"t{i}": smooth_field((40, 50), seed=i) for i in range(6)}
        ctree = codec.compress_tree(tree)
        codec.reset_stats()
        codec.decompress_tree(ctree)
        assert 0 < codec.stats["decode_write_dispatches"] <= \
            codec.config.t_high + 1


def _fused_case(mode: str, eb: float, seed: int = 21):
    """One compressed 1-D tensor per (mode, eb) cell, with forced outliers
    so the fused outlier scatter is exercised end to end.  radius=128 keeps
    the quantization span wider than the outlier band even for range-
    relative bounds (a rel-eb of 1e-3 caps residuals at ~1/(2 eb) = 500, so
    the default radius 512 can never overflow)."""
    x = np.asarray(smooth_field((6000,), seed=seed)).copy()
    x[[37, 2999, 5511]] += np.float32(40.0) * (x.max() - x.min() + 1.0)
    c = Codec(CodecConfig(eb=eb, mode=mode, radius=128)).compress(x)
    assert int((np.asarray(c.outlier_pos) >= 0).sum()) > 0
    return x, c


class TestFusedCodec:
    """``CodecConfig(fused=True)``: bit-exact with the two-pass path over
    the policy matrix, silent recorded fallback everywhere else."""

    @pytest.mark.parametrize("method", ["gap", "selfsync"])
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    @pytest.mark.parametrize("strategy", ["tile", "padded"])
    @pytest.mark.parametrize("mode,eb", [("rel", 1e-3), ("abs", 2e-3)])
    def test_bit_exact_with_two_pass(self, method, backend, strategy, mode,
                                     eb):
        x, c = _fused_case(mode, eb)
        cfg = CodecConfig(eb=eb, mode=mode, method=method, backend=backend,
                          strategy=strategy)
        two, fus = Codec(cfg), Codec(cfg.replace(fused=True))
        two.backend.reset_stats()
        got = np.asarray(fus.decompress(c))
        assert fus.stats["fused_fallbacks"] == 0
        assert fus.stats["fused_dispatches"] >= 1
        want = np.asarray(two.decompress(c))
        assert got.tobytes() == want.tobytes()
        assert np.abs(got - x).max() <= c.eb_effective

    def test_tuned_strategy_falls_back(self):
        x, c = _fused_case("rel", 1e-3)
        codec = Codec(CodecConfig(strategy="tuned", fused=True))
        codec.backend.reset_stats()
        got = np.asarray(codec.decompress(c))
        assert codec.stats["fused_fallbacks"] == 1
        assert codec.stats["fused_dispatches"] == 0
        want = np.asarray(Codec(CodecConfig(strategy="tuned")).decompress(c))
        assert got.tobytes() == want.tobytes()

    def test_nd_tensor_falls_back(self):
        """2-D/3-D are fused-eligible now; >3 non-unit axes still fall
        back (the fused epilogue covers up to 3-D Lorenzo)."""
        codec = Codec(CodecConfig(fused=True))
        c = codec.compress(smooth_field((5, 4, 6, 10), seed=23))
        codec.backend.reset_stats()
        got = np.asarray(codec.decompress(c))
        assert codec.stats["fused_fallbacks"] == 1
        assert codec.stats["fused_dispatches"] == 0
        want = np.asarray(Codec(CodecConfig()).decompress(c))
        assert got.tobytes() == want.tobytes()

    def test_backend_without_fused_ops_falls_back(self):
        """Acceptance: a backend registered without fused ops serves
        fused=True decodes via two-pass, counting every fallback, with
        bit-exact results."""
        ref = hp.get_backend("ref")
        hp.register_backend("nofused-test", lambda: hp.DecodeBackend(
            name="nofused-test", count_fn=ref.count_fn, sync_fn=ref.sync_fn,
            tiles_fn=ref.tiles_fn, padded_fn=ref.padded_fn))
        try:
            x, c = _fused_case("rel", 1e-3)
            codec = Codec(CodecConfig(backend="nofused-test", fused=True))
            assert not codec.backend.supports_fused
            codec.backend.reset_stats()
            got = np.asarray(codec.decompress(c))
            assert codec.stats["fused_fallbacks"] == 1
            want = np.asarray(Codec(CodecConfig()).decompress(c))
            assert got.tobytes() == want.tobytes()
        finally:
            hp._BACKEND_FACTORIES.pop("nofused-test", None)
            hp._BACKENDS.pop("nofused-test", None)

    def test_batch_mixed_eligibility(self):
        """A fused batch decodes eligible (1-D/2-D) tensors through the
        fused dispatch and the rest through the class-merged two-pass path,
        in order, bit-exact, one recorded fallback per ineligible (here
        4-D) tensor."""
        codec = Codec(CodecConfig(fused=True))
        cs = [codec.compress(smooth_field((3000,), seed=31)),
              codec.compress(smooth_field((4, 5, 5, 20), seed=32)),
              codec.compress(smooth_field((20, 25), seed=33)),
              codec.compress(smooth_field((3, 6, 6, 25), seed=34))]
        codec.backend.reset_stats()
        outs = codec.decompress_batch(cs)
        assert codec.stats["fused_fallbacks"] == 2
        assert codec.stats["fused_dispatches"] == 2
        refs = Codec(CodecConfig()).decompress_batch(cs)
        for out, ref in zip(outs, refs):
            assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()

    def test_archive_read_uses_fused_path(self, tmp_path):
        """The store reader threads the codec's fused policy into its
        batched decode."""
        from repro.store import Archive, write_archive

        codec = Codec(CodecConfig(fused=True))
        x = smooth_field((5000,), seed=35)
        c = codec.compress(x)
        path = str(tmp_path / "fused.szt")
        write_archive(path, [("x", c, "float32")])
        codec.backend.reset_stats()
        with Archive(path, codec=codec) as ar:
            out = ar.read_all()["x"]
        assert codec.stats["fused_dispatches"] >= 1
        assert codec.stats["fused_fallbacks"] == 0
        want = Codec(CodecConfig()).decompress(c)
        assert np.asarray(out).tobytes() == np.asarray(want).tobytes()

    def test_invalid_fused_rejected(self):
        with pytest.raises(ValueError, match="fused"):
            CodecConfig(fused="yes")


class TestPlanCacheReuse:
    def test_second_decompress_builds_zero_plans(self):
        codec = Codec()
        c = codec.compress(smooth_field((64, 200), seed=5))
        codec.decompress(c)
        codec.backend.reset_stats()
        codec.decompress(c)
        assert codec.stats["plan_builds"] == 0
        assert codec.stats["plan_hits"] >= 1

    def test_checkpoint_second_restore_builds_zero_plans(self, tmp_path):
        """Acceptance: a second restore of the same step through a shared
        Codec is phase-4 only."""
        from repro.checkpoint.manager import CheckpointManager
        codec = Codec(CodecConfig(eb=1e-3))
        mgr = CheckpointManager(str(tmp_path), codec=codec,
                                compress_min_size=256)
        params = {"embed": jnp.asarray(smooth_field((256, 64), seed=6)),
                  "small": jnp.zeros((4,))}
        mgr.save(0, params)
        first = mgr.restore()
        assert first["step"] == 0
        codec.backend.reset_stats()
        second = mgr.restore()
        assert codec.stats["plan_builds"] == 0
        a = np.asarray(first["params"]["embed"])
        b = np.asarray(second["params"]["embed"])
        assert a.tobytes() == b.tobytes()

    def test_direct_decompress_hits_archive_cached_plan(self, tmp_path):
        """Archive reads and direct Codec.decompress share one key space."""
        from repro.store import Archive, write_archive
        codec = Codec()
        x = smooth_field((48, 128), seed=7)
        c = codec.compress(x)
        path = str(tmp_path / "one.szt")
        write_archive(path, [("x", c, "float32")])
        with Archive(path, codec=codec) as ar:
            out = ar.read_all()
            blob = ar.read_chunk("x")
        codec.backend.reset_stats()
        direct = codec.decompress(blob)
        assert codec.stats["plan_builds"] == 0
        assert np.asarray(direct).tobytes() == \
            np.asarray(out["x"]).tobytes()

    def test_isolated_plan_caches_do_not_share(self):
        a = Codec(CodecConfig(), plan_cache=PlanCache())
        b = Codec(CodecConfig(), plan_cache=PlanCache())
        c = a.compress(smooth_field((32, 64), seed=8))
        a.decompress(c)
        b.backend.reset_stats()
        b.decompress(c)
        assert b.stats["plan_builds"] == 1


class TestShims:
    def test_shims_delegate_to_default_codec(self):
        x = smooth_field((32, 96), seed=9)
        c = api.compress(x)
        assert np.asarray(api.decompress(c)).tobytes() == \
            np.asarray(default_codec().decompress(c)).tobytes()

    def test_removed_flags_raise_typeerror(self):
        x = smooth_field((16, 32), seed=10)
        c = api.compress(x)
        for fn, args in ((api.decompress, (c,)),
                         (api.decompress_batch, ([c],)),
                         (api.compress, (x,))):
            with pytest.raises(TypeError, match="CodecConfig"):
                fn(*args, tuned=True)

    def test_unknown_kwarg_still_typeerror(self):
        with pytest.raises(TypeError, match="frobnicate"):
            api.compress(np.zeros((4, 4), np.float32), frobnicate=1)


class TestErrorListings:
    def test_get_backend_lists_available(self):
        with pytest.raises(ValueError) as ei:
            hp.get_backend("not-a-backend")
        for name in hp.available_backends():
            assert name in str(ei.value)

    def test_decode_lists_valid_strategies(self):
        codec = Codec()
        c = codec.compress(smooth_field((16, 64), seed=12))
        with pytest.raises(ValueError) as ei:
            hp.decode(c.stream, c.codebook, c.n_symbols,
                      strategy="diagonal")
        for s in hp.VALID_STRATEGIES:
            assert s in str(ei.value)

    def test_build_plan_lists_valid_methods(self):
        codec = Codec()
        c = codec.compress(smooth_field((16, 64), seed=13))
        with pytest.raises(ValueError) as ei:
            hp.build_plan(c.stream, c.codebook, method="osmosis")
        for m in hp.VALID_PLAN_METHODS:
            assert m in str(ei.value)


class TestEncodeBackendDigests:
    """Content digests must not depend on which backend wrote the bytes,
    nor on how wide the outlier side list happened to be padded."""

    @staticmethod
    def _lattice(n=5000, eb=0.0078125, seed=11):
        k = np.random.default_rng(seed).integers(-400, 400, n).astype(np.int32)
        return (k.astype(np.float32) * np.float32(2 * eb)), eb

    def test_ref_and_jnp_share_digest_and_plans(self):
        from repro.core.cache import compressed_digest

        x, eb = self._lattice()
        host = Codec(CodecConfig(eb=eb, mode="abs", encode_backend="ref"))
        dev = Codec(CodecConfig(eb=eb, mode="abs", encode_backend="jnp"),
                    plan_cache=host.plan_cache)
        ch, cd = host.compress(x), dev.compress(x)
        assert compressed_digest(ch) == compressed_digest(cd)
        host.decompress(ch)             # builds + inserts the plan
        host.backend.reset_stats()
        host.plan_cache.reset_stats()
        dev.decompress(cd)              # must be a cache hit, not a rebuild
        assert dev.stats["plan_builds"] == 0
        assert host.plan_cache.stats["plan_hits"] >= 1

    def test_digest_ignores_outlier_padding(self):
        """Regression: the digest hashes the valid outlier prefix, so a
        writer that pads the side list wider produces the same digest."""
        import dataclasses

        from repro.core.cache import compressed_digest

        x, eb = self._lattice()
        x[::97] += 1000.0               # force some outliers
        c = Codec(CodecConfig(eb=eb, mode="abs")).compress(x)
        n_valid = int((np.asarray(c.outlier_pos) >= 0).sum())
        assert n_valid > 0
        pos = np.asarray(c.outlier_pos)
        val = np.asarray(c.outlier_val)
        wide = dataclasses.replace(
            c,
            outlier_pos=np.concatenate([pos, np.full(64, -1, pos.dtype)]),
            outlier_val=np.concatenate([val, np.zeros(64, val.dtype)]))
        assert compressed_digest(wide) == compressed_digest(c)
