"""Compressed gradient sync: quantization bounds, error feedback, wire cost.

The multi-device shard_map path runs in a subprocess with a forced 8-device
CPU topology (device count is locked per process)."""

import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import collectives as C


class TestQuantizeEF:
    def test_bound(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(4096),
                        jnp.float32) * 1e-4
        eb = 1e-6
        q, res = C.quantize_ef(x, jnp.zeros_like(x), eb)
        deq = C.dequantize(q, eb)
        unsaturated = np.abs(np.asarray(x)) < 126 * 2 * eb
        err = np.abs(np.asarray(deq) - np.asarray(x))
        assert err[unsaturated].max() <= eb + 1e-12

    def test_error_feedback_accumulates(self):
        """A constant tiny gradient below the quantization step must still
        flow through after enough steps (residual accumulation)."""
        eb = 1e-3
        g = jnp.full((8,), 0.4 * 2 * eb)  # below half-step: rounds to 0
        res = jnp.zeros((8,))
        total = np.zeros(8)
        for _ in range(10):
            q, res = C.quantize_ef(g, res, eb)
            total += np.asarray(C.dequantize(q, eb))
        # after 10 steps the emitted sum ~ 10 * g
        assert np.allclose(total, 10 * np.asarray(g), atol=2 * eb)


class TestWireBytes:
    def test_scheme_ordering(self):
        n = 10_000_000
        f32 = C.wire_bytes(n, "allreduce_f32")
        bf16 = C.wire_bytes(n, "allreduce_bf16")
        comp = C.wire_bytes(n, "rs_bf16_ag_int8")
        assert f32 > bf16 > comp
        assert f32 / comp == pytest.approx(8 / 3, rel=1e-6)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import collectives as C

    mesh = make_host_mesh(data=8)
    sync, init_res = C.make_dp_gradient_sync(mesh, eb=1e-7)
    rng = np.random.default_rng(0)
    # per-shard gradients stacked on the data axis
    g = jnp.asarray(rng.standard_normal((8, 1024)).astype(np.float32)) * 1e-3
    grads = {"w": g}
    res = init_res(grads)
    out, res = sync(grads, res)
    want = np.mean(np.asarray(g), axis=0)
    got = np.asarray(out["w"])  # every shard row holds the mean
    err = float(max(np.abs(got[i] - want).max() for i in range(8)))
    print(json.dumps({"err": err}))
""")


class TestShardMapSync:
    def test_compressed_mean_close(self, tmp_path):
        p = subprocess.run([sys.executable, "-c", SUBPROC],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"})
        assert p.returncode == 0, p.stderr[-2000:]
        err = json.loads(p.stdout.strip().splitlines()[-1])["err"]
        # bf16 reduce-scatter + int8 wire: error ~ bf16 rounding of mean
        assert err < 5e-5, err
