"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode as D
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw

ARCHS = list(configs.REGISTRY)


def _batch_for(cfg, batch, seq):
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    out = {"tokens": toks, "labels": labels}
    if cfg.family == "vlm":
        p = 8
        out["extra_embeds"] = jnp.zeros((batch, p, cfg.d_model), cfg.cdt)
        out["labels"] = jnp.concatenate(
            [jnp.full((batch, p), -1, jnp.int32), labels], axis=1)
    elif cfg.family == "encdec":
        out["extra_embeds"] = jnp.zeros(
            (batch, cfg.encoder_seq, cfg.d_model), cfg.cdt)
    return out


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train(self, arch):
        cfg = configs.get_config(arch).reduced()
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        batch = _batch_for(cfg, batch=2, seq=32)
        logits, aux = T.forward(params, batch["tokens"], cfg,
                                extra_embeds=batch.get("extra_embeds"))
        s_total = batch["labels"].shape[1] if cfg.family != "encdec" else 32
        assert logits.shape == (2, s_total, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

        ocfg = adamw.AdamWConfig(lr=1e-3)
        step = S.make_train_step(cfg, ocfg)
        p2, o2, m = step(params, adamw.init(params, ocfg), batch)
        assert np.isfinite(float(m["loss"]))
        assert np.isfinite(float(m["grad_norm"]))
        # params actually changed
        deltas = [float(np.abs(np.asarray(a, np.float32)
                               - np.asarray(b, np.float32)).max())
                  for a, b in zip(jax.tree.leaves(params),
                                  jax.tree.leaves(p2))]
        assert max(deltas) > 0

    def test_decode_step(self, arch):
        cfg = configs.get_config(arch).reduced()
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        cache = D.init_cache(cfg, batch=2, kv_len=64)
        serve = S.make_serve_step(cfg)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, new_cache = serve(params, tok, cache, jnp.int32(0))
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert set(new_cache) == set(cache)
        for k in cache:
            assert new_cache[k].shape == cache[k].shape, k


class TestDecodeConsistency:
    """Greedy decode over a prompt must match the parallel forward."""

    @pytest.mark.parametrize("arch", ["qwen3-0.6b", "h2o-danube-1.8b",
                                      "rwkv6-3b", "zamba2-7b",
                                      "deepseek-v3-671b"])
    def test_decode_matches_forward(self, arch):
        cfg = configs.get_config(arch).reduced(n_layers=2)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                  cfg.vocab)
        full, _ = T.forward(params, toks, cfg)
        cache = D.init_cache(cfg, 1, 16)
        serve = S.make_serve_step(cfg)
        lg = None
        for t in range(8):
            lg, cache = serve(params, toks[:, t:t + 1], cache, jnp.int32(t))
        a = np.asarray(lg[0, 0], np.float32)
        b = np.asarray(full[0, -1], np.float32)
        # compare top-choice agreement + numeric closeness
        assert np.abs(a - b).max() < 5e-2, np.abs(a - b).max()
        assert a.argmax() == b.argmax()


class TestConfigRegistry:
    def test_all_archs_present(self):
        assert len(configs.REGISTRY) == 10

    def test_cell_count(self):
        # 10 archs x 4 shapes - 7 long_500k skips = 33
        assert len(configs.cells()) == 33
        assert len(configs.cells(include_skipped=True)) == 40

    def test_exact_assigned_dims(self):
        c = configs.get_config("deepseek-v3-671b")
        assert (c.n_layers, c.d_model, c.n_heads) == (61, 7168, 128)
        assert (c.n_experts, c.top_k, c.d_expert) == (256, 8, 2048)
        c = configs.get_config("qwen2-vl-72b")
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == \
            (80, 8192, 64, 8)
        c = configs.get_config("rwkv6-3b")
        assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == \
            (32, 2560, 8960, 65536)

    def test_param_counts_close_to_nameplate(self):
        from repro.models.config import count_params
        expect = {"qwen3-0.6b": 0.6e9, "deepseek-v3-671b": 671e9,
                  "qwen2-vl-72b": 72e9, "qwen2.5-3b": 3.1e9}
        for name, n in expect.items():
            got = count_params(configs.get_config(name))
            assert abs(got - n) / n < 0.15, (name, got)
