import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_book_and_stream(rng, n_syms=4000, vocab=1024, zipf=1.4, max_len=12,
                         subseqs_per_seq=32):
    """Shared helper: random codebook + encoded stream."""
    from repro.core.huffman import codebook as cb, encode as he

    freq = np.bincount(np.clip(rng.zipf(zipf, 30000), 0, vocab - 1),
                       minlength=vocab)
    book = cb.build_codebook(freq, max_len=max_len)
    probs = freq / freq.sum()
    syms = rng.choice(vocab, size=n_syms, p=probs).astype(np.uint16)
    stream = he.encode(syms, book.enc_code, book.enc_len,
                       subseqs_per_seq=subseqs_per_seq)
    return book, syms, stream
