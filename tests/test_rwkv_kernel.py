"""Pallas chunked-GLA kernel vs the exact RWKV-6 recurrence (§Perf A)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv_gla import (gla_time_mix, hbm_bytes_kernel,
                                    hbm_bytes_xla)


def _ref(r, k, v, w, u):
    bh, s, dk = r.shape
    dv = v.shape[-1]
    ys = []
    state = np.zeros((bh, dk, dv), np.float32)
    for t in range(s):
        kv = k[:, t, :, None] * v[:, t, None, :]
        ys.append((r[:, t, :, None] * (state + u[:, :, None] * kv)).sum(1))
        state = w[:, t, :, None] * state + kv
    return np.stack(ys, 1)


@pytest.mark.parametrize("bh,s,dk,dv,chunk", [
    (2, 64, 8, 8, 16),
    (4, 128, 16, 16, 32),
    (1, 96, 32, 16, 32),   # dk != dv, s not a power of two
    (3, 64, 64, 64, 64),   # full rwkv6 head dims, single chunk
])
def test_matches_reference(bh, s, dk, dv, chunk):
    rng = np.random.default_rng(bh * s + dk)
    r = rng.standard_normal((bh, s, dk)).astype(np.float32)
    k = rng.standard_normal((bh, s, dk)).astype(np.float32)
    v = rng.standard_normal((bh, s, dv)).astype(np.float32)
    w = rng.uniform(0.1, 0.999, (bh, s, dk)).astype(np.float32)
    u = rng.standard_normal((bh, dk)).astype(np.float32)
    y = np.asarray(gla_time_mix(*map(jnp.asarray, (r, k, v, w, u)),
                                chunk=chunk))
    np.testing.assert_allclose(y, _ref(r, k, v, w, u), rtol=1e-4, atol=1e-4)


def test_extreme_decay_stable():
    """w near 0 (hard forget) must not produce NaN/inf -- the log-space
    chunked formulations struggle exactly here (see models/rwkv.py)."""
    rng = np.random.default_rng(0)
    bh, s, dk, dv = 2, 64, 16, 16
    w = np.full((bh, s, dk), 1e-6, np.float32)
    r = rng.standard_normal((bh, s, dk)).astype(np.float32)
    k = rng.standard_normal((bh, s, dk)).astype(np.float32)
    v = rng.standard_normal((bh, s, dv)).astype(np.float32)
    u = np.zeros((bh, dk), np.float32)
    y = np.asarray(gla_time_mix(*map(jnp.asarray, (r, k, v, w, u)),
                                chunk=16))
    assert np.isfinite(y).all()
    np.testing.assert_allclose(y, _ref(r, k, v, w, u), rtol=1e-4, atol=1e-4)


def test_traffic_model_improvement():
    """The kernel's HBM model must beat the XLA per-step scan by ~dk/2."""
    b, h, s, dk, dv, layers = 16, 40, 4096, 64, 64, 32
    before = hbm_bytes_xla(b, h, s, dk, dv, layers)
    after = hbm_bytes_kernel(b, h, s, dk, dv, layers)
    assert before / after > 20   # dk/2.5 = 25x nominal
