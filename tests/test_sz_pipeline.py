"""End-to-end SZ pipeline: error-bound property, ratios, shapes, methods."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import api
from repro.data.pipeline import smooth_field


class TestErrorBound:
    @pytest.mark.parametrize("shape", [(4096,), (100, 173), (24, 31, 17),
                                       (4, 10, 11, 13)])
    @pytest.mark.parametrize("eb", [1e-2, 1e-3, 1e-4])
    def test_bound_holds(self, shape, eb):
        x = smooth_field(shape, seed=hash(shape) % 2**31)
        c = api.compress(x, eb=eb, mode="rel")
        for method in ("gap", "selfsync", "naive_ref"):
            xh = np.asarray(api.decompress(c, method=method))
            assert np.abs(xh - x).max() <= c.eb_effective, method

    def test_outlier_heavy(self, rng):
        x = (rng.standard_normal(3000) * 50).astype(np.float32)
        c = api.compress(x, eb=1e-4, mode="abs")
        xh = np.asarray(api.decompress(c, method="gap"))
        assert np.abs(xh - x).max() <= c.eb_effective

    def test_constant_field(self):
        x = np.full((512,), 2.5, np.float32)
        c = api.compress(x, eb=1e-3)
        xh = np.asarray(api.decompress(c))
        assert np.abs(xh - x).max() <= c.eb_effective

    @settings(max_examples=10, deadline=None)
    @given(st.integers(16, 3000), st.floats(1e-4, 1e-1), st.integers(0, 2**31))
    def test_property(self, n, eb, seed):
        r = np.random.default_rng(seed)
        x = np.cumsum(r.standard_normal(n)).astype(np.float32)
        c = api.compress(x, eb=eb, mode="rel")
        xh = np.asarray(api.decompress(c, method="gap"))
        assert np.abs(xh - x).max() <= c.eb_effective


class TestRatio:
    def test_smooth_beats_noise(self, rng):
        smooth = smooth_field((256, 256), seed=1)
        noise = rng.standard_normal((256, 256)).astype(np.float32)
        cs = api.compress(smooth, eb=1e-3)
        cn = api.compress(noise, eb=1e-3)
        assert cs.ratio > cn.ratio
        assert cs.ratio > 3.0

    def test_larger_eb_larger_ratio(self):
        x = smooth_field((128, 512), seed=2)
        r = [api.compress(x, eb=e).ratio for e in (1e-4, 1e-3, 1e-2)]
        assert r[0] < r[1] < r[2]

    def test_paper_ratio_regime(self):
        """cuSZ at rel-eb 1e-3 reports ratios ~2.3-16 (paper Table IV);
        our surrogate smooth fields should land inside that band."""
        x = smooth_field((512, 512), seed=3)
        c = api.compress(x, eb=1e-3)
        assert 2.0 < c.ratio < 40.0


class TestKernelPath:
    def test_kernel_decompress_matches(self, rng):
        x = smooth_field((64, 700), seed=4)
        c = api.compress(x, eb=1e-3)
        a = np.asarray(api.decompress(c, method="gap", backend="ref"))
        b = np.asarray(api.decompress(c, method="gap", backend="pallas"))
        assert np.array_equal(a, b)

    def test_removed_flags_raise_pointing_at_codec_config(self, rng):
        x = smooth_field((32, 200), seed=6)
        c = api.compress(x, eb=1e-3)
        for bad in ({"use_tiles": False}, {"use_kernels": True},
                    {"tuned": True}):
            with pytest.raises(TypeError, match="CodecConfig"):
                api.decompress(c, **bad)
        with pytest.raises(TypeError, match="CodecConfig"):
            api.compress(x, use_kernels=True)
