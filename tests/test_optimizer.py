"""AdamW (fp32 + int8 block-quantized state) behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("state_dtype", ["float32", "int8"])
def test_converges_on_quadratic(state_dtype):
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                            state_dtype=state_dtype)
    params = {"w": jnp.zeros((130,)), "b": jnp.ones((257,))}
    state = adamw.init(params, cfg)
    for _ in range(300):
        grads = jax.grad(quad_loss)(params)
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(quad_loss(params)) < 1e-2


def test_int8_tracks_fp32():
    params = {"w": jnp.linspace(-1, 1, 256)}
    g = {"w": jnp.ones((256,)) * 0.1}
    cfg32 = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0)
    cfg8 = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0, state_dtype="int8")
    p32, s32 = dict(params), adamw.init(params, cfg32)
    p8, s8 = dict(params), adamw.init(params, cfg8)
    for _ in range(20):
        p32, s32, _ = adamw.update(g, s32, p32, cfg32)
        p8, s8, _ = adamw.update(g, s8, p8, cfg8)
    diff = np.abs(np.asarray(p32["w"]) - np.asarray(p8["w"])).max()
    assert diff < 5e-3, diff


def test_quantize_state_bounds():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 1024)),
                    jnp.float32)
    s = adamw.quantize_state(x)
    assert s["q"].dtype == jnp.int8
    assert s["q"].shape == x.shape          # param-shaped (sharding parity)
    back = adamw.dequantize_state(s, (8, 1024))
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127 + 1e-7


def test_quantize_state_fallback_f32():
    # last dim not a multiple of 128 -> exact f32 fallback
    x = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    jnp.float32)
    s = adamw.quantize_state(x)
    assert "f" in s
    assert np.array_equal(np.asarray(adamw.dequantize_state(s, (1000,))),
                          np.asarray(x))


def test_grad_clip():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw.update(huge, state, params, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip
