"""End-to-end system behaviour (replaces the scaffold placeholder)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import api
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import decode as D
from repro.models import kvcache
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw


class TestTrainingLoop:
    def test_loss_decreases(self):
        cfg = configs.get_config("qwen3-0.6b").reduced()
        ocfg = adamw.AdamWConfig(lr=1e-3)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=4, seed=0))
        step_fn = jax.jit(S.make_train_step(cfg, ocfg))
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params, ocfg)
        losses = []
        for s in range(25):
            params, opt, m = step_fn(params, opt, data.batch_at(s))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
        assert all(np.isfinite(losses))


class TestServeWithCompressedKV:
    def test_compressed_cache_roundtrip_serving(self):
        """The paper's in-memory use case: compress the cache mid-serve and
        keep decoding; logits must stay close to the uncompressed path."""
        cfg = configs.get_config("qwen3-0.6b").reduced(n_layers=2)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        serve = S.make_serve_step(cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab)
        cache = D.init_cache(cfg, 2, 32)
        for t in range(8):
            _, cache = serve(params, toks[:, t:t + 1], cache, jnp.int32(t))

        from repro.core import Codec, CodecConfig
        codec = Codec(CodecConfig(eb=1e-3))
        cc = kvcache.compress_cache(cache, codec=codec)
        restored = kvcache.decompress_cache(cc, codec=codec)
        for k in cache:
            a = np.asarray(cache[k], np.float32)
            b = np.asarray(restored[k], np.float32)
            # compressor bound + half-ulp of the cast back to the cache
            # dtype (bf16 has 8 mantissa bits)
            bound = cc.blobs[k].eb_effective + float(np.abs(a).max()) * 2**-8
            assert np.abs(a - b).max() <= bound, (k, float(np.abs(a-b).max()))

        lg_a, _ = serve(params, toks[:, 8:9], dict(cache), jnp.int32(8))
        lg_b, _ = serve(params, toks[:, 8:9], restored, jnp.int32(8))
        diff = np.abs(np.asarray(lg_a, np.float32)
                      - np.asarray(lg_b, np.float32)).max()
        assert diff < 0.15, diff


class TestCompressedCheckpointTrainOn:
    def test_restore_and_continue(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        cfg = configs.get_config("qwen3-0.6b").reduced(n_layers=1)
        ocfg = adamw.AdamWConfig(lr=1e-3)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=2, seed=1))
        step_fn = jax.jit(S.make_train_step(cfg, ocfg))
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params, ocfg)
        for s in range(3):
            params, opt, _ = step_fn(params, opt, data.batch_at(s))

        from repro.core import Codec, CodecConfig
        mgr = CheckpointManager(str(tmp_path),
                                codec=Codec(CodecConfig(eb=1e-4)),
                                compress_min_size=4096)
        mgr.save(2, params, opt)
        r = mgr.restore()
        p2, o2 = r["params"], r["opt"]
        # training continues and stays finite from lossy-restored weights
        for s in range(3, 6):
            p2, o2, m = step_fn(p2, o2, data.batch_at(s))
        assert np.isfinite(float(m["loss"]))


class TestCompressorAsLibrary:
    def test_blob_accounting(self):
        from repro.data.pipeline import smooth_field
        x = smooth_field((256, 256), seed=5)
        c = api.compress(x, eb=1e-3)
        assert c.original_bytes == 256 * 256 * 4
        assert c.compressed_bytes < c.original_bytes
        assert c.quant_code_bytes == 2 * 256 * 256
