"""Sharding rules: divisibility-safety for every arch's param tree (runs on
an 8-device forced topology in a subprocess; jit-argument shardings must
divide exactly)."""

import json
import subprocess
import sys
import textwrap

import pytest

SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro import configs
    from repro.models import transformer as T, decode as D
    from repro.runtime import sharding as shd
    from repro.optim import adamw

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=4, model=2)
    bad = []
    for arch, cfg in configs.REGISTRY.items():
        ps = jax.eval_shape(lambda c=cfg: T.init_model(jax.random.PRNGKey(0), c))
        shards = shd.param_shardings(ps, mesh)

        def check(kp, x, s):
            spec = s.spec
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                n = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= mesh.shape[a]
                if x.shape[i] % n != 0:
                    bad.append((arch, str(kp), x.shape, str(spec)))

        jax.tree_util.tree_map_with_path(check, ps, shards)
        # opt state
        ocfg = adamw.AdamWConfig(state_dtype="int8")
        os_ = jax.eval_shape(lambda p=ps: adamw.init(p, ocfg), )
        oshards = shd.opt_state_shardings(os_, mesh)
        jax.tree_util.tree_map_with_path(check, os_, oshards)
        # decode caches
        cs = {k: jax.ShapeDtypeStruct(shape, dt)
              for k, (shape, dt) in D.cache_spec(cfg, 8, 256).items()}
        cshards = shd.cache_shardings(cs, mesh)
        jax.tree_util.tree_map_with_path(check, cs, cshards)
    print(json.dumps({"bad": bad[:10], "n_bad": len(bad)}))
""")


def test_all_param_specs_divide():
    p = subprocess.run([sys.executable, "-c", SUB], capture_output=True,
                       text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert p.returncode == 0, p.stderr[-2000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["n_bad"] == 0, out["bad"]
