"""Additional property suites: chunked encoder, tuner invariance, kv quant."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.huffman import codebook as cb
from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he
from repro.core.huffman import pipeline as hp


class TestChunkedEncoderProperty:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(100, 3000), st.sampled_from([64, 512, 1000]),
           st.integers(0, 2**31))
    def test_roundtrip_any_chunk(self, n, chunk, seed):
        r = np.random.default_rng(seed)
        syms = r.integers(0, 300, size=n).astype(np.uint16)
        freq = np.bincount(syms, minlength=300)
        book = cb.build_codebook(freq, max_len=12)
        ch = he.encode_chunked(syms, book.enc_code, book.enc_len,
                               chunk_symbols=chunk)
        out = hd.decode_chunked(ch["units"], ch["chunk_bits"],
                                ch["chunk_syms"], jnp.asarray(book.dec_sym),
                                jnp.asarray(book.dec_len),
                                max_len=12, chunk_symbols=chunk)
        assert np.array_equal(np.asarray(out).reshape(-1)[:n], syms)

    def test_chunk_padding_costs_ratio(self, rng):
        """Smaller chunks => more unit-alignment padding (paper §III-A)."""
        syms = rng.integers(0, 64, size=20000).astype(np.uint16)
        freq = np.bincount(syms, minlength=64)
        book = cb.build_codebook(freq, max_len=10)
        small = he.encode_chunked(syms, book.enc_code, book.enc_len, 128)
        large = he.encode_chunked(syms, book.enc_code, book.enc_len, 8192)
        assert small["stored_bytes"] >= large["stored_bytes"]


class TestTunerInvariance:
    @pytest.mark.parametrize("t_high", [4, 8, 12])
    def test_output_independent_of_t_high(self, rng, t_high):
        from conftest import make_book_and_stream
        book, syms, stream = make_book_and_stream(rng, n_syms=8000)
        ds, dl = jnp.asarray(book.dec_sym), jnp.asarray(book.dec_len)
        starts = hd.gap_starts(stream)
        bnds = jnp.arange(stream.gaps.shape[0], dtype=jnp.int32) * 128
        _, counts = hd.subseq_scan(jnp.asarray(stream.units), ds, dl,
                                   starts, bnds + 128, stream.total_bits,
                                   book.max_len)
        out = hp.execute_tuned(stream, ds, dl, book.max_len, len(syms),
                                  starts, counts, t_high=t_high)
        assert np.array_equal(np.asarray(out), syms)


class TestKVQuantFamilywide:
    @pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen2.5-3b",
                                      "h2o-danube-1.8b", "qwen2-vl-72b"])
    def test_int8_kv_decode_close(self, arch):
        from repro import configs
        from repro.models import decode as D, steps as S, transformer as T

        cfg = configs.get_config(arch).reduced(n_layers=2)
        cfg_q = dataclasses.replace(cfg, kv_quant=True)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                  cfg.vocab)
        cache_a = D.init_cache(cfg, 1, 16)
        cache_b = D.init_cache(cfg_q, 1, 16)
        sa, sb = S.make_serve_step(cfg), S.make_serve_step(cfg_q)
        for t in range(8):
            la, cache_a = sa(params, toks[:, t:t + 1], cache_a, jnp.int32(t))
            lb, cache_b = sb(params, toks[:, t:t + 1], cache_b, jnp.int32(t))
        a = np.asarray(la[0, 0], np.float32)
        b = np.asarray(lb[0, 0], np.float32)
        assert a.argmax() == b.argmax()
        assert np.abs(a - b).max() < 0.25, np.abs(a - b).max()
