"""Data pipeline determinism + online tuning plan correctness."""

import jax.numpy as jnp
import numpy as np

from repro.core.huffman import pipeline as hp
from repro.data.pipeline import DataConfig, SyntheticLM, smooth_field

from conftest import make_book_and_stream


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab=500, seq_len=32, global_batch=4, seed=3)
        a = SyntheticLM(cfg).batch_at(7)
        b = SyntheticLM(cfg).batch_at(7)
        assert np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))

    def test_steps_differ(self):
        cfg = DataConfig(vocab=500, seq_len=32, global_batch=4)
        d = SyntheticLM(cfg)
        assert not np.array_equal(np.asarray(d.batch_at(0)["tokens"]),
                                  np.asarray(d.batch_at(1)["tokens"]))

    def test_shards_differ(self):
        a = SyntheticLM(DataConfig(vocab=500, seq_len=32, global_batch=8,
                                   n_shards=2, shard_id=0)).batch_at(0)
        b = SyntheticLM(DataConfig(vocab=500, seq_len=32, global_batch=8,
                                   n_shards=2, shard_id=1)).batch_at(0)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2))
        b = d.batch_at(0)
        assert np.array_equal(np.asarray(b["labels"])[:, :-1],
                              np.asarray(b["tokens"])[:, 1:])
        assert (np.asarray(b["labels"])[:, -1] == -1).all()

    def test_zipf_marginals_skewed(self):
        d = SyntheticLM(DataConfig(vocab=1000, seq_len=256, global_batch=8,
                                   mode="zipf"))
        toks = np.asarray(d.batch_at(0)["tokens"]).reshape(-1)
        counts = np.bincount(toks, minlength=1000)
        assert counts[:10].sum() > counts[500:510].sum()

    def test_smooth_field_compressible(self):
        from repro.core import api
        x = smooth_field((128, 128), seed=0)
        assert api.compress(x, eb=1e-3).ratio > 2


class TestTuningPlan:
    def test_classify_matches_paper_groups(self):
        ratios = jnp.asarray([0.5, 1.0, 1.5, 3.2, 8.0, 15.9])
        cls = np.asarray(hp.classify(ratios, t_high=8))
        assert list(cls) == [1, 1, 2, 4, 8, 9]

    def test_tile_for_class(self):
        assert hp.tile_for_class(1) == 1024
        assert hp.tile_for_class(4) == 4096
        assert hp.tile_for_class(9, t_high=8) == hp.OVERFLOW_TILE

    def test_plan_partitions_everything(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=20000)
        plan = hp.make_plan(stream, stream.seq_counts,
                                stream.subseqs_per_seq)
        n_seq = stream.n_seq
        assert sorted(plan.seq_order.tolist()) == list(range(n_seq))
        assert plan.class_start[-1] == n_seq
        # class boundaries consistent with classes
        cls_sorted = plan.classes[plan.seq_order]
        assert (np.diff(cls_sorted) >= 0).all()

    def test_ratio_range_maps_into_groups(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=20000)
        ratios = hp.sequence_ratios(stream.seq_counts,
                                        stream.subseqs_per_seq)
        r = np.asarray(ratios)
        assert (r > 0).all() and (r <= 16.0 + 1e-6).all()
