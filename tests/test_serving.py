"""Serving scheduler: concurrency safety, batching, prefix sharing.

Covers the ``repro.serving`` subsystem plus the thread-safety contracts it
leans on: a ``KVPager``/``Codec``/``PlanCache`` shared by N threads must be
bit-exact with serial use and keep deterministic dispatch counters
(single-flight plan builds), the ``BlockCache`` must never evict pinned
entries, and the ``DecodeScheduler`` must decode each distinct block
content exactly once no matter how requests interleave.
"""

import concurrent.futures as futures
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Codec, CodecConfig
from repro.core.huffman import pipeline as hp
from repro.serving import (BlockCache, DecodeScheduler, build_corpus,
                           percentile, run_load, summarize_ttft)
from repro.serving.loadgen import check_invariants
from repro.store import KVPager, PageLostError, PlanCache


def _codec(eb=1e-3):
    """A codec with its own plan cache (isolated from the default)."""
    return Codec(CodecConfig(eb=eb), plan_cache=PlanCache())


def _cache(seed=0, s=32):
    k = jax.random.PRNGKey(seed)
    base = jnp.cumsum(jax.random.normal(k, (2, 1, s, 2, 8)) * 0.05, axis=2)
    return {"k": base, "v": base + 0.5}


def _offload_blocks(pager, n=4, s_per=8, seed=0):
    """n blocks with distinct contents; returns their ids."""
    cache = _cache(seed=seed, s=n * s_per)
    ids = []
    for i in range(n):
        cache, bid = pager.offload(cache, i * s_per, (i + 1) * s_per)
        ids.append(bid)
    return ids


# ---------------------------------------------------------------------------
# KVPager thread safety + satellite fixes
# ---------------------------------------------------------------------------


class TestPagerConcurrency:
    def test_ratio_zero_when_idle(self, tmp_path):
        assert KVPager(str(tmp_path), codec=_codec()).ratio == 0.0

    def test_drop_unknown_raises_named_error(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        with pytest.raises(PageLostError):
            pager.drop(12345)

    def test_fetch_many_matches_fetch(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=3)
        serial = {bid: pager.fetch(bid) for bid in ids}
        batched = pager.fetch_many(ids)
        assert set(batched) == set(serial)
        for bid in ids:
            for name in serial[bid]:
                assert np.array_equal(np.asarray(batched[bid][name]),
                                      np.asarray(serial[bid][name]))

    def test_concurrent_fetch_bit_exact_and_counters(self, tmp_path):
        """N threads through one shared pager+codec: results identical to
        serial, plans built exactly once per distinct chunk."""
        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=4)
        serial = {bid: {n: np.asarray(a)
                        for n, a in pager.fetch(bid).items()}
                  for bid in ids}

        fresh = KVPager(pager.dir, codec=_codec())
        for bid in ids:
            fresh.adopt_block(bid, pager.block_meta(bid))
        be = hp.get_backend(fresh.codec.config.backend)
        before = dict(be.stats)
        with futures.ThreadPoolExecutor(8) as ex:
            got = list(ex.map(
                lambda bid: (bid, fresh.fetch(bid)), ids * 4))
        for bid, tensors in got:
            for name, arr in tensors.items():
                assert np.array_equal(np.asarray(arr), serial[bid][name])
        # Single-flight plan building: 2 chunks (k, v) per block, each
        # distinct payload planned once no matter the thread count.
        built = be.stats["plan_builds"] - before.get("plan_builds", 0)
        assert built == 2 * len(ids)
        assert fresh.stats["pages_in"] == 4 * len(ids)

    def test_concurrent_offload_unique_ids(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        out = []
        lock = threading.Lock()

        def one(seed):
            cache = _cache(seed=seed, s=8)
            _, bid = pager.offload(cache, 0, 8)
            with lock:
                out.append(bid)

        threads = [threading.Thread(target=one, args=(i,)) for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(set(out)) == 6
        assert pager.stats["pages_out"] == 6


class TestSharedCodecThreads:
    def test_decompress_threads_bit_exact_with_serial(self):
        codec = _codec()
        rng = np.random.default_rng(0)
        xs = [np.cumsum(rng.normal(size=2048).astype(np.float32))
              for _ in range(4)]
        cs = [codec.compress(x) for x in xs]
        serial = [np.asarray(codec.decompress(c)) for c in cs]

        cold = _codec()
        be = hp.get_backend(cold.config.backend)
        before = dict(be.stats)
        with futures.ThreadPoolExecutor(8) as ex:
            got = list(ex.map(lambda c: np.asarray(cold.decompress(c)),
                              cs * 4))
        for i, arr in enumerate(got):
            assert np.array_equal(arr, serial[i % len(cs)])
        # Deterministic counters under contention: one plan build per
        # distinct stream (single-flight), not per thread.
        assert (be.stats["plan_builds"]
                - before.get("plan_builds", 0)) == len(cs)


# ---------------------------------------------------------------------------
# BlockCache
# ---------------------------------------------------------------------------


class TestBlockCache:
    def test_hit_miss_and_lru_eviction(self):
        c = BlockCache(capacity_bytes=100)
        c.insert("a", {"x": 1}, 40, pinned=False)
        c.insert("b", {"x": 2}, 40, pinned=False)
        assert c.acquire("a") == {"x": 1}    # refresh a; pins it too
        c.release("a")
        c.insert("c", {"x": 3}, 40, pinned=False)   # evicts b (LRU)
        assert "b" not in c
        assert "a" in c and "c" in c
        assert c.stats["evictions"] == 1
        assert c.acquire("b") is None
        assert c.stats["misses"] == 1

    def test_pinned_entries_never_evicted(self):
        c = BlockCache(capacity_bytes=100)
        c.insert("a", {"x": 1}, 60, pinned=True)     # in flight
        c.insert("b", {"x": 2}, 60, pinned=False)    # over capacity now
        assert "a" in c                              # pinned survives
        assert "b" not in c                          # unpinned LRU paid
        c.release("a")
        c.insert("d", {"x": 4}, 60, pinned=False)    # a unpinned -> evictable
        assert "a" not in c

    def test_admission_reject_oversized(self):
        c = BlockCache(capacity_bytes=100)
        assert c.insert("big", {"x": 0}, 101) is False
        assert "big" not in c
        assert c.stats["admission_rejects"] == 1

    def test_double_insert_keeps_existing(self):
        c = BlockCache(capacity_bytes=100)
        assert c.insert("a", {"x": 1}, 10, pinned=False) is True
        assert c.insert("a", {"x": 2}, 10, pinned=False) is False
        assert c.acquire("a") == {"x": 1}

    def test_release_unknown_ignored(self):
        BlockCache(100).release("ghost")


# ---------------------------------------------------------------------------
# DecodeScheduler
# ---------------------------------------------------------------------------


class TestScheduler:
    def test_results_bit_exact_with_direct_fetch(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=3)
        direct = {bid: {n: np.asarray(a)
                        for n, a in pager.fetch(bid).items()}
                  for bid in ids}
        with DecodeScheduler(pager, batch_window_s=0.001) as sched:
            got = sched.fetch(0, ids)
        for bid in ids:
            for name, arr in got[bid].items():
                assert np.array_equal(np.asarray(arr), direct[bid][name])

    @pytest.mark.parametrize("overlap", [True, False])
    def test_shared_content_decodes_once(self, tmp_path, overlap):
        """Same block ids requested by many sessions AND distinct ids with
        identical bytes: every distinct content decodes exactly once."""
        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=2, seed=7)
        # A twin block: identical content offloaded under a new id.
        twin_src = KVPager(str(tmp_path) + "_twin", codec=_codec())
        twin_ids = _offload_blocks(twin_src, n=2, seed=7)
        twin_map = {}
        for bid in twin_ids:
            meta = twin_src.block_meta(bid)
            new_id = 100 + bid
            pager.adopt_block(new_id, meta)
            twin_map[bid] = new_id

        n_sessions = 6
        with DecodeScheduler(pager, batch_window_s=0.02,
                             overlap=overlap) as sched:
            futs = []
            for sid in range(n_sessions):
                wanted = ids if sid % 2 == 0 else [twin_map[b]
                                                  for b in twin_ids]
                futs += [sched.submit(sid, bid) for bid in wanted]
            for f in futs:
                f.result()
            st = dict(sched.stats)
        # 2 distinct contents behind 4 block ids and 12 requests.
        assert st["blocks_decoded"] == 2
        assert st["requests"] == n_sessions * 2
        assert st["prefix_hits"] + st["coalesced_requests"] == \
            n_sessions * 2 - 2

    def test_lost_block_fails_only_its_futures(self, tmp_path):
        import os

        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=2)
        os.unlink(pager.block_meta(ids[0])["path"])
        with DecodeScheduler(pager, batch_window_s=0.001) as sched:
            bad = sched.submit(0, ids[0])
            good = sched.submit(1, ids[1])
            assert good.result()     # batch-mate unaffected
            with pytest.raises(PageLostError):
                bad.result()
            assert sched.stats["blocks_lost"] == 1
        assert pager.stats["pages_lost"] == 1

    def test_fairness_cap_defers_large_sessions(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=4)
        with DecodeScheduler(pager, batch_window_s=0.05,
                             max_blocks_per_session_per_tick=1) as sched:
            futs = [sched.submit(0, bid) for bid in ids]
            futs.append(sched.submit(1, ids[0]))
            for f in futs:
                f.result()
            assert sched.stats["deferred"] >= 1
            assert sched.stats["ticks"] >= 4

    def test_submit_after_close_raises(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        ids = _offload_blocks(pager, n=1)
        sched = DecodeScheduler(pager, batch_window_s=0.001)
        sched.close()
        with pytest.raises(RuntimeError):
            sched.submit(0, ids[0])

    def test_invalid_knobs_rejected(self, tmp_path):
        pager = KVPager(str(tmp_path), codec=_codec())
        with pytest.raises(ValueError):
            DecodeScheduler(pager, batch_window_s=-1)
        with pytest.raises(ValueError):
            DecodeScheduler(pager, max_blocks_per_session_per_tick=0)


# ---------------------------------------------------------------------------
# Sessions / load generator
# ---------------------------------------------------------------------------


class TestSessions:
    def test_percentile_nearest_rank(self):
        xs = [10.0, 20.0, 30.0, 40.0, 50.0]
        assert percentile(xs, 0) == 10.0
        assert percentile(xs, 100) == 50.0
        assert percentile(xs, 50) == 30.0
        with pytest.raises(ValueError):
            percentile([], 50)

    def test_summarize_empty(self):
        out = summarize_ttft([])
        assert out["n"] == 0 and np.isnan(out["p50_ms"])


class TestLoadgen:
    def test_invariants_hold_end_to_end(self, tmp_path):
        corpus = build_corpus(str(tmp_path), n_sessions=6, prefix_blocks=2,
                              unique_blocks=1, tokens_per_block=4, seed=0)
        assert corpus.n_distinct_blocks == 2 + 6
        assert corpus.n_block_requests == 6 * 3
        base = run_load(corpus, mode="baseline", rate_per_s=2000.0, seed=0)
        schd = run_load(corpus, mode="scheduler", rate_per_s=2000.0, seed=0,
                        batch_window_s=0.005)
        check_invariants(corpus, base, schd)

    def test_unknown_mode_rejected(self, tmp_path):
        corpus = build_corpus(str(tmp_path), n_sessions=1, prefix_blocks=1,
                              unique_blocks=1, tokens_per_block=4, seed=0)
        with pytest.raises(ValueError):
            run_load(corpus, mode="warp")
