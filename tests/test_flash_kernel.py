"""Pallas flash-attention kernel vs dense-softmax oracle (§Perf D)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import (flash_attention, hbm_bytes_kernel,
                                      hbm_bytes_xla)


def _ref(q, k, v, causal):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask, s, -1e30)
    return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1),
                      v.astype(jnp.float32))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,sq,skv,d,dv,bq,bk", [
    (2, 128, 128, 32, 32, 64, 64),
    (1, 256, 128, 64, 64, 64, 128),   # rectangular (cross-attn shape)
    (3, 128, 128, 16, 32, 32, 64),    # dv != d (MLA value dims)
    (2, 512, 512, 128, 128, 128, 128),  # full TPU tile shapes
])
def test_matches_reference(causal, bh, sq, skv, d, dv, bq, bk):
    rng = np.random.default_rng(sq + skv + d)
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, skv, dv)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    r = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=2e-5, atol=2e-5)


def test_bf16_io():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    assert out.dtype == jnp.bfloat16
    r = _ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=2e-2)


def test_extreme_logits_stable():
    """Large-magnitude scores must not overflow the running softmax."""
    q = jnp.full((1, 64, 16), 30.0, jnp.float32)
    k = jnp.full((1, 64, 16), 30.0, jnp.float32)
    v = jnp.ones((1, 64, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)


def test_traffic_model_improvement():
    # starcoder2 train_4k attention shapes: B_loc=16, H=48, S=4096
    before = hbm_bytes_xla(16, 48, 4096, 4096, 128)
    after = hbm_bytes_kernel(16, 48, 4096, 4096, 128)
    assert before / after > 30   # S/(2*d) * (4B/2B) regime


def test_trainable_gradients_match_xla():
    from repro.kernels.flash_attn import (_xla_attention,
                                          flash_attention_trainable)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32)

    def loss_kernel(q, k, v):
        return flash_attention_trainable(q, k, v, True, 64, 64).sum()

    def loss_xla(q, k, v):
        return _xla_attention(q, k, v, True).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gx = jax.grad(loss_xla, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
