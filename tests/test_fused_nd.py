"""N-D / low-precision fused decode: the property-based parity suite.

The fused decode→dequantize→inverse-Lorenzo path now covers the full
ndim {1,2,3} x dtype {f32, bf16, f16} lattice.  Correctness across that
lattice -- on both backends, both fused-capable strategies, and both error
bound modes, with outliers forced past the quantization radius -- is the
whole risk, so this module asserts, cell by cell:

    fused  ==  two-pass (same backend)  ==  two-pass ("ref" backend)

bit-for-bit, with ``stats["fused_dispatches"]`` counted and
``stats["fused_fallbacks"]`` zero for every supported cell.  A seeded
deterministic sweep always runs; when ``hypothesis`` is installed the same
invariant is additionally driven over randomized shapes (the
``tests/test_faults.py`` pattern).  Checked-in golden vectors
(``tests/golden/fused_nd_golden.json``) pin the compressed bytes and the
reconstruction checksums across versions, hypothesis or not.
"""

import hashlib
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import Codec, CodecConfig
from repro.core.huffman import pipeline as hp
from repro.core.sz import compressor as sz
from repro.data.pipeline import smooth_field

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # container has no hypothesis
    HAVE_HYPOTHESIS = False

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "fused_nd_golden.json")

SHAPES = {1: (6000,), 2: (56, 72), 3: (6, 24, 40)}
DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float16}
RADIUS = 128      # small radius so the forced spikes overflow it
TILE_SYMS = 512   # small tiles so every decode crosses many carry chains


def _field(shape, dtype, seed):
    """Lorenzo-friendly field with spikes guaranteed past the radius.

    The spikes make ``|residual| >= radius`` at known positions, so the
    outlier side list -- and the fused kernels' outlier scatter -- is
    exercised in every cell (asserted in ``_make_case``).
    """
    x = np.asarray(smooth_field(shape, seed=seed)).copy()
    flat = x.reshape(-1)
    rng = np.random.default_rng(seed + 1000)
    idx = rng.choice(flat.size, size=max(4, flat.size // 400), replace=False)
    flat[idx] += np.float32(40.0) * (x.max() - x.min() + 1.0) * \
        rng.choice(np.asarray([-1.0, 1.0], np.float32), size=idx.size)
    return jnp.asarray(x).astype(dtype)


_CASES: dict = {}


def _make_case(ndim, dtype_key, mode, eb):
    """One compressed tensor + its two-pass ref baseline per lattice cell
    (memoized: compression is the expensive part of every cell)."""
    key = (ndim, dtype_key, mode, eb)
    if key not in _CASES:
        x = _field(SHAPES[ndim], DTYPES[dtype_key], seed=7 * ndim + 13)
        codec = Codec(CodecConfig(eb=eb, mode=mode, radius=RADIUS,
                                  tile_syms=TILE_SYMS))
        c = codec.compress(x)
        assert int((np.asarray(c.outlier_pos) >= 0).sum()) > 0, \
            "case must exercise the outlier scatter"
        want = np.asarray(codec.decompress(c))   # two-pass on "ref"
        _CASES[key] = (x, c, want)
    return _CASES[key]


class TestFusedNdParity:
    """fused == two-pass == ref over the full eligibility lattice."""

    @pytest.mark.parametrize("mode,eb", [("rel", 1e-4), ("abs", 1e-3)])
    @pytest.mark.parametrize("dtype_key", list(DTYPES))
    @pytest.mark.parametrize("ndim", [1, 2, 3])
    @pytest.mark.parametrize("strategy", ["tile", "padded"])
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    def test_lattice_cell(self, backend, strategy, ndim, dtype_key, mode, eb):
        x, c, want = _make_case(ndim, dtype_key, mode, eb)
        cfg = CodecConfig(eb=eb, mode=mode, radius=RADIUS,
                          tile_syms=TILE_SYMS, backend=backend,
                          strategy=strategy)
        fus = Codec(cfg.replace(fused=True))
        fus.backend.reset_stats()
        got = np.asarray(fus.decompress(c))
        assert fus.stats["fused_fallbacks"] == 0
        assert fus.stats["fused_dispatches"] == 1
        assert got.dtype == np.dtype(c.dtype) and got.shape == tuple(c.shape)
        # fused == two-pass on the SAME backend ...
        same = np.asarray(Codec(cfg).decompress(c))
        assert got.tobytes() == same.tobytes()
        # ... == two-pass on the ref backend (the jnp oracle).
        assert got.tobytes() == want.tobytes()
        # And the reconstruction honors the dtype-aware guarantee.
        err = np.abs(got.astype(np.float64) - np.asarray(
            x, np.float32).astype(np.float64)).max()
        assert err <= c.eb_effective

    def test_unit_axes_squeeze(self):
        """(1, R, C) reconstructs through the 2-D epilogue, bit-exact."""
        x = _field((1, 56, 72), jnp.float32, seed=5)
        codec = Codec(CodecConfig(eb=1e-4, radius=RADIUS, fused=True,
                                  tile_syms=TILE_SYMS))
        c = codec.compress(x)
        codec.backend.reset_stats()
        got = np.asarray(codec.decompress(c))
        assert codec.stats["fused_fallbacks"] == 0
        want = np.asarray(
            Codec(CodecConfig(eb=1e-4, radius=RADIUS,
                              tile_syms=TILE_SYMS)).decompress(c))
        assert got.tobytes() == want.tobytes()

    def test_acceptance_2d_f32_and_1d_bf16(self):
        """The ISSUE's acceptance cells, spelled out: 2-D float32 and 1-D
        bfloat16 fused decodes are bit-exact with two-pass on both
        backends, dispatches counted, zero fallbacks."""
        for ndim, dtype_key in ((2, "f32"), (1, "bf16")):
            x, c, want = _make_case(ndim, dtype_key, "rel", 1e-4)
            for backend in ("ref", "pallas"):
                cfg = CodecConfig(eb=1e-4, radius=RADIUS,
                                  tile_syms=TILE_SYMS, backend=backend,
                                  fused=True)
                codec = Codec(cfg)
                codec.backend.reset_stats()
                got = np.asarray(codec.decompress(c))
                assert codec.stats["fused_fallbacks"] == 0
                assert codec.stats["fused_dispatches"] == 1
                assert got.tobytes() == want.tobytes()


class TestFusedNdEligibility:
    def test_reasons(self):
        be = hp.get_backend("ref")
        ok = _make_case(2, "f32", "rel", 1e-4)[1]
        assert sz.fused_unsupported_reason(ok, be, "gap", "tile") is None
        assert sz.fused_unsupported_reason(ok, be, "gap", "padded") is None
        assert "tuned" in sz.fused_unsupported_reason(ok, be, "gap", "tuned")
        assert "oracle" in sz.fused_unsupported_reason(
            ok, be, "naive_ref", "tile")
        # 4 non-unit axes: beyond the 3-D epilogue.
        codec = Codec(CodecConfig(eb=1e-3, radius=RADIUS))
        c4 = codec.compress(smooth_field((4, 5, 6, 8), seed=2))
        assert "4-D" in sz.fused_unsupported_reason(c4, be, "gap", "tile")
        # float64 stays two-pass (synthesized: jnp truncates f64 inputs at
        # compress, so a real f64 Compressed never arises on this build).
        import dataclasses

        c64 = dataclasses.replace(ok, dtype=np.dtype(np.float64))
        assert "float64" in sz.fused_unsupported_reason(
            c64, be, "gap", "tile")

    def test_width_bounds(self):
        """Tensors past the VMEM row/plane provisioning report a reason
        (without paying for a huge compress: synthesize the metadata)."""
        be = hp.get_backend("ref")
        base = _make_case(2, "f32", "rel", 1e-4)[1]
        import dataclasses

        wide = dataclasses.replace(
            base, shape=(4, sz.FUSED_MAX_COLS + 1))
        assert "fastest axis" in sz.fused_unsupported_reason(
            wide, be, "gap", "tile")
        deep = dataclasses.replace(
            base, shape=(4, 2048, (sz.FUSED_MAX_PLANE // 2048) + 1))
        assert "plane" in sz.fused_unsupported_reason(
            deep, be, "gap", "tile")


class TestFallbackAccounting:
    """``fused_fallbacks`` counts each ineligible tensor exactly once, for
    every entry point that can decode many tensors."""

    def _mixed(self):
        codec = Codec(CodecConfig(eb=1e-3, radius=RADIUS))
        return codec, [
            codec.compress(smooth_field((3000,), seed=41)),       # eligible
            codec.compress(smooth_field((4, 5, 6, 10), seed=42)),  # 4-D
            codec.compress(smooth_field((20, 25), seed=43)),      # eligible
            codec.compress(smooth_field((3, 6, 6, 25), seed=44)),  # 4-D
        ]

    @pytest.mark.parametrize("strategy", ["tile", "padded"])
    def test_batch_counts_per_tensor(self, strategy):
        _, cs = self._mixed()
        codec = Codec(CodecConfig(eb=1e-3, radius=RADIUS, fused=True,
                                  strategy=strategy))
        codec.backend.reset_stats()
        outs = codec.decompress_batch(cs)
        assert codec.stats["fused_fallbacks"] == 2
        assert codec.stats["fused_dispatches"] == 2
        want = Codec(CodecConfig(eb=1e-3, radius=RADIUS)).decompress_batch(cs)
        for got, ref in zip(outs, want):
            assert np.asarray(got).tobytes() == np.asarray(ref).tobytes()

    def test_single_decompress_counts_once(self):
        _, cs = self._mixed()
        codec = Codec(CodecConfig(eb=1e-3, radius=RADIUS, fused=True))
        codec.backend.reset_stats()
        codec.decompress(cs[1])
        assert codec.stats["fused_fallbacks"] == 1

    def test_tuned_strategy_batch_falls_back_per_tensor(self):
        """With a non-fusable strategy every tensor is ineligible: the
        counter equals the tensor count, not the call count."""
        _, cs = self._mixed()
        codec = Codec(CodecConfig(eb=1e-3, radius=RADIUS, fused=True,
                                  strategy="tuned"))
        codec.backend.reset_stats()
        codec.decompress_batch(cs)
        assert codec.stats["fused_fallbacks"] == len(cs)
        assert codec.stats["fused_dispatches"] == 0


# ---------------------------------------------------------------------------
# Golden vectors: cross-version regression anchors without hypothesis
# ---------------------------------------------------------------------------


def _golden_case(spec):
    x = _field(tuple(spec["shape"]), DTYPES[spec["dtype"]], spec["seed"])
    codec = Codec(CodecConfig(eb=spec["eb"], mode=spec["mode"],
                              radius=spec["radius"],
                              tile_syms=spec["tile_syms"]))
    return x, codec, codec.compress(x)


def _compressed_digest(c) -> str:
    h = hashlib.sha256()
    h.update(np.asarray(c.stream.units).tobytes())
    h.update(np.asarray(c.stream.gaps).tobytes())
    h.update(int(c.stream.total_bits).to_bytes(8, "little"))
    h.update(np.asarray(c.outlier_pos).tobytes())
    h.update(np.asarray(c.outlier_val).tobytes())
    return h.hexdigest()


class TestGoldenVectors:
    def test_golden(self):
        """Compressed bytes AND reconstructions match the checked-in
        fixture: encode and decode are both pinned across versions."""
        with open(GOLDEN_PATH) as f:
            golden = json.load(f)
        assert golden["cases"], "fixture must not be empty"
        for entry in golden["cases"]:
            spec = entry["spec"]
            _, codec, c = _golden_case(spec)
            assert _compressed_digest(c) == entry["compressed_sha256"], \
                f"compressed bytes drifted for {spec}"
            two = np.asarray(codec.decompress(c))
            assert hashlib.sha256(two.tobytes()).hexdigest() == \
                entry["reconstruction_sha256"], \
                f"two-pass reconstruction drifted for {spec}"
            fus = Codec(codec.config.replace(fused=True))
            got = np.asarray(fus.decompress(c))
            assert hashlib.sha256(got.tobytes()).hexdigest() == \
                entry["reconstruction_sha256"], \
                f"fused reconstruction drifted for {spec}"


# ---------------------------------------------------------------------------
# Hypothesis: the same invariant over randomized shapes (when available)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        ndim=st.integers(1, 3),
        dtype_key=st.sampled_from(list(DTYPES)),
        strategy=st.sampled_from(["tile", "padded"]),
        dims=st.lists(st.integers(3, 40), min_size=3, max_size=3),
        seed=st.integers(0, 2**16),
    )
    def test_fused_parity_property(ndim, dtype_key, strategy, dims, seed):
        shape = tuple(dims[:ndim])
        x = _field(shape, DTYPES[dtype_key], seed)
        cfg = CodecConfig(eb=1e-3, radius=RADIUS, tile_syms=TILE_SYMS,
                          strategy=strategy)
        codec = Codec(cfg)
        c = codec.compress(x)
        want = np.asarray(codec.decompress(c))
        fus = Codec(cfg.replace(fused=True))
        fus.backend.reset_stats()
        got = np.asarray(fus.decompress(c))
        assert fus.stats["fused_fallbacks"] == 0
        assert got.tobytes() == want.tobytes()
