"""Fault-injection hardening: recovery policies, decoder guards, salvage
restore, lost KV pages, and the corruption-campaign harness itself."""

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointIntegrityError,
                                      CheckpointManager)
from repro.core import Codec, CodecConfig
from repro.core.cache import PlanCache
from repro.core.huffman import pipeline as hp
from repro.core.sz import compressor as sz
from repro.data.pipeline import smooth_field
from repro.models import kvcache
from repro.runtime import fault_tolerance as ft
from repro.store import (Archive, ArchiveWriter, KVPager, PageLostError,
                         StoreCorruptError, StoreError, StoreIOError)
from repro.testing import NAMED_ERRORS, flip_bit, run_campaign

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # container has no hypothesis
    HAVE_HYPOTHESIS = False


def _codec(**kw):
    kw.setdefault("eb", 1e-3)
    return Codec(CodecConfig(**kw), plan_cache=PlanCache())


def _write(path, codec, names=("t0", "t1", "t2", "t3"), seed=0):
    arrays = {}
    with ArchiveWriter(path, codec=codec) as w:
        for i, n in enumerate(names):
            arrays[n] = np.asarray(smooth_field((40, 36 + 4 * i),
                                                seed=seed + i), np.float32)
            w.add_array(n, arrays[n])
    return arrays


def _flip_in_chunk(path, codec, name, rng):
    with Archive(path, codec=codec) as ar:
        rec = ar.chunk(name)
    flip_bit(path, rec.units.offset + int(rng.integers(rec.units.length)),
             int(rng.integers(8)))


# ---------------------------------------------------------------------------
# RecoveryPolicy / with_retries units
# ---------------------------------------------------------------------------


class TestRecoveryPolicy:
    def test_validation(self):
        with pytest.raises(ValueError):
            ft.RecoveryPolicy(on_error="explode")
        with pytest.raises(ValueError):
            ft.RecoveryPolicy(retries=-1)

    def test_resolve_inherits_config(self):
        codec = _codec(recovery="zero_fill", io_retries=5, io_backoff=0.5)
        pol = codec.recovery_policy()
        assert (pol.on_error, pol.retries, pol.backoff) == \
            ("zero_fill", 5, 0.5)
        # a bare string overrides on_error but keeps the IO knobs
        pol = codec.recovery_policy("skip")
        assert (pol.on_error, pol.retries) == ("skip", 5)
        # a full policy instance passes through untouched
        mine = ft.RecoveryPolicy(retries=9)
        assert codec.recovery_policy(mine) is mine

    def test_config_rejects_bad_recovery(self):
        with pytest.raises(ValueError):
            CodecConfig(recovery="panic")

    def test_with_retries_transient(self):
        calls, sleeps = [], []
        def fn():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("flaky")
            return "ok"
        pol = ft.RecoveryPolicy(retries=3, backoff=0.1, multiplier=2.0)
        assert ft.with_retries(fn, pol, sleep=sleeps.append) == "ok"
        assert len(calls) == 3
        assert sleeps == [0.1, 0.2]

    def test_with_retries_exhausted_and_selective(self):
        def always(): raise OSError("down")
        with pytest.raises(OSError):
            ft.with_retries(always, ft.RecoveryPolicy(retries=2),
                            sleep=lambda s: None)
        # deterministic corruption must never be retried
        calls = []
        def corrupt():
            calls.append(1)
            raise StoreCorruptError("bad crc")
        with pytest.raises(StoreCorruptError):
            ft.with_retries(corrupt, ft.RecoveryPolicy(retries=5),
                            sleep=lambda s: None)
        assert len(calls) == 1


# ---------------------------------------------------------------------------
# Decoder-level guards
# ---------------------------------------------------------------------------


class TestDecoderGuards:
    def test_corrupt_codebook_rejected_at_build_plan(self):
        codec = _codec()
        c = codec.compress(jnp.asarray(smooth_field((64, 32), seed=3),
                                       jnp.float32))
        bad_len = np.array(c.codebook.enc_len)
        used = np.flatnonzero(bad_len)
        bad_len[used[: max(2, used.size // 2)]] = 1   # Kraft sum > 1
        book = dataclasses.replace(c.codebook, enc_len=bad_len)
        before = hp.get_backend("ref").stats["decode_guard_trips"]
        with pytest.raises(hp.DecodeGuardError, match="codebook"):
            hp.build_plan(c.stream, book, method="gap", backend="ref")
        assert hp.get_backend("ref").stats["decode_guard_trips"] == before + 1

    def test_dec_len_over_max_rejected(self):
        codec = _codec()
        c = codec.compress(jnp.asarray(smooth_field((64, 32), seed=4),
                                       jnp.float32))
        dec_len = np.array(c.codebook.dec_len)
        dec_len[0] = c.codebook.max_len + 7
        book = dataclasses.replace(c.codebook, dec_len=dec_len)
        with pytest.raises(hp.DecodeGuardError):
            hp.build_plan(c.stream, book, method="gap", backend="ref")

    def test_symbol_count_mismatch_guard(self):
        codec = _codec()
        c = codec.compress(jnp.asarray(smooth_field((64, 32), seed=5),
                                       jnp.float32))
        # claim half the bits: the plan decodes fewer symbols than shape
        stream = dataclasses.replace(
            c.stream, total_bits=jnp.asarray(int(c.stream.total_bits) // 2,
                                             jnp.int32))
        bad = dataclasses.replace(c, stream=stream)
        with pytest.raises(hp.DecodeGuardError, match="symbol-count"):
            _codec().decompress(bad)

    def test_oversized_gap_clamped_not_crashed(self):
        codec = _codec()
        c = codec.compress(jnp.asarray(smooth_field((64, 32), seed=6),
                                       jnp.float32))
        gaps = np.array(c.stream.gaps)
        gaps[gaps.size // 2] = 255        # legit gaps never exceed 128
        stream = dataclasses.replace(c.stream, gaps=jnp.asarray(gaps))
        before = hp.get_backend("ref").stats["decode_guard_trips"]
        hp.build_plan(stream, c.codebook, method="gap", backend="ref")
        assert hp.get_backend("ref").stats["decode_guard_trips"] == before + 1

    def test_guard_trips_key_in_stats(self):
        assert "decode_guard_trips" in hp.get_backend("ref").stats


# ---------------------------------------------------------------------------
# Single-byte-flip property: named error OR bit-exact, never silent
# ---------------------------------------------------------------------------


class TestSingleByteFlip:
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    def test_seeded_sweep(self, tmp_path, backend):
        codec = _codec(backend=backend)
        path = str(tmp_path / "a.szt")
        _write(path, codec, names=("x", "y"))
        with Archive(path, codec=codec) as ar:
            baseline = {n: np.asarray(v) for n, v in ar.read_all().items()}
        size, pristine = os.path.getsize(path), open(path, "rb").read()
        rng = np.random.default_rng(0)
        for _ in range(40):
            with open(path, "wb") as f:
                f.write(pristine)
            flip_bit(path, int(rng.integers(size)), int(rng.integers(8)))
            self._check_one(path, codec, baseline)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=60, deadline=None)
        @given(frac=st.floats(0, 1, exclude_max=True),
               bit=st.integers(0, 7))
        def test_property(self, tmp_path_factory, frac, bit):
            codec = _codec()
            d = tmp_path_factory.mktemp("flip")
            path = str(d / "a.szt")
            _write(path, codec, names=("x", "y"))
            with Archive(path, codec=codec) as ar:
                baseline = {n: np.asarray(v)
                            for n, v in ar.read_all().items()}
            flip_bit(path, int(frac * os.path.getsize(path)), bit)
            self._check_one(path, codec, baseline)

    @staticmethod
    def _check_one(path, codec, baseline):
        """The invariant: a flipped archive either raises a named error or
        round-trips bit-exactly (flip landed in dead bytes)."""
        try:
            with Archive(path, codec=codec) as ar:
                out = ar.read_all(policy="raise")
        except NAMED_ERRORS:
            return
        assert set(out) == set(baseline)
        for n in baseline:
            assert np.asarray(out[n]).tobytes() == baseline[n].tobytes(), \
                f"{n}: silent corruption"


# ---------------------------------------------------------------------------
# Archive recovery policies + prefetch error propagation
# ---------------------------------------------------------------------------


class TestArchiveRecovery:
    def test_policies(self, tmp_path):
        codec = _codec()
        path = str(tmp_path / "a.szt")
        arrays = _write(path, codec)
        rng = np.random.default_rng(1)
        _flip_in_chunk(path, codec, "t2", rng)

        with Archive(path, codec=codec) as ar:
            with pytest.raises(StoreCorruptError, match="t2"):
                ar.read_all(policy="raise")

        seen = []
        with Archive(path, codec=codec) as ar:
            out = ar.read_all(policy="skip",
                              on_error=lambda n, e: seen.append((n, e)))
            assert sorted(out) == ["t0", "t1", "t3"]
            assert ar.stats["chunks_skipped"] == 1
        assert seen[0][0] == "t2"
        assert isinstance(seen[0][1], StoreError)

        with Archive(path, codec=codec) as ar:
            out = ar.read_all(policy="zero_fill")
            assert sorted(out) == ["t0", "t1", "t2", "t3"]
            assert not np.any(np.asarray(out["t2"]))
            assert out["t2"].shape == arrays["t2"].shape
            assert ar.stats["chunks_zero_filled"] == 1

    def test_codec_config_default_policy(self, tmp_path):
        codec = _codec(recovery="skip")
        path = str(tmp_path / "a.szt")
        _write(path, codec)
        _flip_in_chunk(path, codec, "t0", np.random.default_rng(2))
        with Archive(path, codec=codec) as ar:
            out = ar.read_all()           # no per-call policy: config wins
            assert sorted(out) == ["t1", "t2", "t3"]

    def test_prefetch_error_reaches_consumer(self, tmp_path):
        """Regression: a corrupt chunk in a *later* prefetched group must
        surface to the iterating consumer, not die with the thread."""
        codec = _codec()
        path = str(tmp_path / "a.szt")
        names = tuple(f"t{i}" for i in range(6))
        _write(path, codec, names=names)
        _flip_in_chunk(path, codec, "t5", np.random.default_rng(3))
        with Archive(path, codec=codec) as ar:
            it = ar.iter_decode(group_chunks=2, prefetch=True,
                                policy="raise")
            got = []
            with pytest.raises(StoreCorruptError, match="t5"):
                for n, _ in it:
                    got.append(n)
        assert got == ["t0", "t1", "t2", "t3", "t4"]

    def test_transient_io_retried_then_named(self, tmp_path):
        from repro.testing.faults import inject_blob_failures
        codec = _codec(io_retries=2)
        path = str(tmp_path / "a.szt")
        _write(path, codec, names=("x",))
        with Archive(path, codec=codec) as ar:
            baseline = np.asarray(ar.read_tensor("x"))
        with Archive(path, codec=codec) as ar:
            inject_blob_failures(ar, 2)
            out = ar.read_all(policy="raise")
            assert np.asarray(out["x"]).tobytes() == baseline.tobytes()
            assert ar.stats["io_retries"] >= 1
        with Archive(path, codec=codec) as ar:
            inject_blob_failures(ar, 10 ** 6)
            with pytest.raises(StoreIOError):
                ar.read_all(policy="raise")


# ---------------------------------------------------------------------------
# Checkpoint salvage
# ---------------------------------------------------------------------------


def _ckpt(tmp_path, codec):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, codec=codec, compress_min_size=1024)
    rng = np.random.default_rng(7)
    params = {"w1": rng.normal(size=(48, 48)).astype(np.float32),
              "w2": rng.normal(size=(40, 40)).astype(np.float32),
              "n": np.int32(9)}
    mgr.save(1, params)
    mgr.save(2, params)
    return d, mgr, params


class TestCheckpointSalvage:
    def test_atomic_manifest_write(self, tmp_path):
        d, mgr, _ = _ckpt(tmp_path, _codec())
        step = os.path.join(d, "step_00000002")
        assert os.path.exists(os.path.join(step, "manifest.json"))
        assert not os.path.exists(
            os.path.join(step, "manifest.json.tmp"))
        r = mgr.restore()
        assert r["step"] == 2 and not r["quarantined"]

    def test_skip_quarantines_corrupt_entry(self, tmp_path):
        codec = _codec()
        d, mgr, params = _ckpt(tmp_path, codec)
        apath = os.path.join(d, "step_00000002", "archive.szt")
        with Archive(apath, codec=codec) as ar:
            rec = ar.chunk("params.w1")
        flip_bit(apath, rec.units.offset + rec.units.length // 2, 3)

        with pytest.raises(CheckpointIntegrityError):
            mgr.restore(2)                # default policy: raise
        r = mgr.restore(2, policy="skip")
        assert list(r["quarantined"]) == ["params.w1"]
        assert "w1" not in r["params"]
        assert np.allclose(np.asarray(r["params"]["w2"]), params["w2"],
                           atol=1e-2)
        assert int(r["params"]["n"]) == 9

    def test_zero_fill_keeps_tree_structure(self, tmp_path):
        codec = _codec()
        d, mgr, params = _ckpt(tmp_path, codec)
        apath = os.path.join(d, "step_00000002", "archive.szt")
        os.unlink(apath)                  # lose the whole archive
        r = mgr.restore(2, policy="zero_fill")
        assert set(r["quarantined"]) == {"params.w1", "params.w2"}
        assert r["params"]["w1"].shape == params["w1"].shape
        assert not np.any(np.asarray(r["params"]["w1"]))
        assert int(r["params"]["n"]) == 9

    def test_torn_manifest_falls_back_to_newest_intact(self, tmp_path):
        codec = _codec()
        d, mgr, params = _ckpt(tmp_path, codec)
        mpath = os.path.join(d, "step_00000002", "manifest.json")
        with open(mpath, "r+b") as f:
            f.truncate(os.path.getsize(mpath) // 2)
        with pytest.raises(CheckpointIntegrityError):
            mgr.restore()                 # raise: newest step is torn
        r = mgr.restore(policy="skip")
        assert r["step"] == 1
        assert r["fallback_from"][0]["step"] == 2
        assert np.allclose(np.asarray(r["params"]["w1"]), params["w1"],
                           atol=1e-2)

    def test_corrupt_raw_shard_named_and_quarantined(self, tmp_path):
        codec = _codec()
        d, mgr, _ = _ckpt(tmp_path, codec)
        npy = os.path.join(d, "step_00000002", "params.n.npy")
        flip_bit(npy, os.path.getsize(npy) - 1, 0)
        with pytest.raises(CheckpointIntegrityError, match="params.n"):
            mgr.restore(2)
        r = mgr.restore(2, policy="skip")
        assert "params.n" in r["quarantined"]
        assert "w1" in r["params"]


# ---------------------------------------------------------------------------
# KV paging degradation
# ---------------------------------------------------------------------------


def _paged(tmp_path, codec):
    pager = KVPager(str(tmp_path / "kv"), codec=codec, seq_axis=2)
    rng = np.random.default_rng(11)
    cache = {k: jnp.asarray(rng.normal(size=(1, 1, 16, 8)), jnp.float32)
             for k in ("k", "v")}
    cache, bid = pager.offload(cache, 0, 8, keys=["k", "v"])
    return pager, cache, bid


class TestPagingDegradation:
    def test_lost_block_named_counted_evicted(self, tmp_path):
        codec = _codec()
        pager, cache, bid = _paged(tmp_path, codec)
        os.unlink(pager.block_meta(bid)["path"])
        with pytest.raises(PageLostError) as ei:
            pager.page_in(cache, bid)
        assert ei.value.block_id == bid
        assert pager.stats["pages_lost"] == 1
        assert bid not in pager.resident_blocks
        # the paged span is untouched (still zeroed): safe degraded state
        assert not np.any(np.asarray(cache["k"][:, :, :8]))

    def test_corrupt_block_named(self, tmp_path):
        codec = _codec()
        pager, cache, bid = _paged(tmp_path, codec)
        path = pager.block_meta(bid)["path"]
        flip_bit(path, os.path.getsize(path) // 2, 5)
        with pytest.raises(PageLostError):
            pager.page_in(cache, bid)

    def test_page_in_blocks_on_lost_continues(self, tmp_path):
        codec = _codec()
        pager = KVPager(str(tmp_path / "kv"), codec=codec, seq_axis=2)
        rng = np.random.default_rng(13)
        cache = {k: jnp.asarray(rng.normal(size=(1, 1, 16, 8)), jnp.float32)
                 for k in ("k", "v")}
        snap = {k: np.asarray(v) for k, v in cache.items()}
        cache, b0 = pager.offload(cache, 0, 8, keys=["k", "v"])
        cache, b1 = pager.offload(cache, 8, 16, keys=["k", "v"])
        os.unlink(pager.block_meta(b0)["path"])
        lost = []
        cache = kvcache.page_in_blocks(cache, pager, [b0, b1],
                                       on_lost=lambda b, e: lost.append(b))
        assert lost == [b0]
        assert not np.any(np.asarray(cache["k"][:, :, :8]))   # stays zeroed
        assert np.allclose(np.asarray(cache["k"][:, :, 8:]),
                           snap["k"][:, :, 8:], atol=1e-2)    # restored
        # without the callback the named error propagates
        with pytest.raises(PageLostError):
            kvcache.page_in_blocks(cache, pager, [b0])

    def test_adopt_block_reregisters(self, tmp_path):
        codec = _codec()
        pager, cache, bid = _paged(tmp_path, codec)
        meta = pager.block_meta(bid)
        fresh = KVPager(pager.dir, codec=codec, seq_axis=2)
        fresh.adopt_block(bid, meta)
        out = fresh.fetch(bid)
        assert set(out) == {"k", "v"}
        with pytest.raises(ValueError, match="missing keys"):
            fresh.adopt_block(99, {"path": "x"})


# ---------------------------------------------------------------------------
# The campaign harness itself
# ---------------------------------------------------------------------------


class TestCampaign:
    def test_small_campaign_clean(self, tmp_path):
        report = run_campaign(seed=1, cases=8,
                              base_dir=str(tmp_path / "campaign"))
        assert len(report.results) == 8
        assert report.ok, report.summary()
        # every consumer exercised at least once
        assert {r.case.consumer for r in report.results} == \
            {"store", "decode", "checkpoint", "paging"}

    def test_deterministic_schedule(self, tmp_path):
        a = run_campaign(seed=2, cases=4, base_dir=str(tmp_path / "a"))
        b = run_campaign(seed=2, cases=4, base_dir=str(tmp_path / "b"))
        assert [(r.case.kind, r.case.seed) for r in a.results] == \
            [(r.case.kind, r.case.seed) for r in b.results]
