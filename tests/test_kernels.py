"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.huffman import decode as hd
from repro.core.huffman.pipeline import ss_max_for_tile
from repro.kernels import ops, ref

from conftest import make_book_and_stream


def _luts(book):
    return jnp.asarray(book.dec_sym), jnp.asarray(book.dec_len)


class TestCountKernel:
    @pytest.mark.parametrize(
        "n", [500, 4096, pytest.param(9001, marks=pytest.mark.slow)])
    @pytest.mark.parametrize("zipf", [1.2, 2.0])
    def test_matches_ref(self, rng, n, zipf):
        book, syms, stream = make_book_and_stream(rng, n_syms=n, zipf=zipf)
        ds, dl = _luts(book)
        nss = stream.gaps.shape[0]
        bnds = jnp.arange(nss, dtype=jnp.int32) * 128
        starts = bnds + stream.gaps.astype(jnp.int32)
        ck, _ = ops.subseq_counts(stream.units, ds, dl, starts, bnds + 128,
                                  stream.total_bits, book.max_len)
        cr, _ = ref.subseq_counts(stream.units, ds, dl, starts, bnds + 128,
                                  stream.total_bits, book.max_len)
        assert np.array_equal(np.asarray(ck), np.asarray(cr))
        assert int(np.asarray(ck).sum()) == n


@pytest.mark.slow
class TestDecodeTilesKernel:
    @pytest.mark.parametrize("tile", [1024, 3584, 4096])
    def test_matches_ref(self, rng, tile):
        book, syms, stream = make_book_and_stream(rng, n_syms=7000)
        ds, dl = _luts(book)
        nss = stream.gaps.shape[0]
        bnds = jnp.arange(nss, dtype=jnp.int32) * 128
        starts = bnds + stream.gaps.astype(jnp.int32)
        _, counts = hd.subseq_scan(jnp.asarray(stream.units), ds, dl, starts,
                                   bnds + 128, stream.total_bits,
                                   book.max_len)
        offsets = hd.output_offsets(counts)
        ss_max = ss_max_for_tile(tile, book.max_len)
        k = ops.decode_write_tiles(stream.units, ds, dl, starts, bnds + 128,
                                   offsets, stream.total_bits, book.max_len,
                                   7000, tile, ss_max)
        r = ref.decode_write_tiles(stream.units, ds, dl, starts, bnds + 128,
                                   offsets, stream.total_bits, book.max_len,
                                   7000, tile, ss_max)
        assert np.array_equal(np.asarray(k), np.asarray(r))
        assert np.array_equal(np.asarray(k), syms)

    def test_padded_baseline_matches(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=3000)
        ds, dl = _luts(book)
        nss = stream.gaps.shape[0]
        bnds = jnp.arange(nss, dtype=jnp.int32) * 128
        starts = bnds + stream.gaps.astype(jnp.int32)
        out_k, c_k = ops.decode_padded_compact(
            stream.units, ds, dl, starts, bnds + 128, stream.total_bits,
            book.max_len, 3000)
        out_r, c_r = ref.decode_padded_compact(
            stream.units, ds, dl, starts, bnds + 128, stream.total_bits,
            book.max_len, 3000)
        assert np.array_equal(np.asarray(out_k), np.asarray(out_r))
        assert np.array_equal(np.asarray(c_k), np.asarray(c_r))
        assert np.array_equal(np.asarray(out_k), syms)


@pytest.mark.slow
class TestSelfsyncKernel:
    @pytest.mark.parametrize("early_exit", [True, False])
    def test_matches_ref(self, rng, early_exit):
        book, syms, stream = make_book_and_stream(rng, n_syms=5000)
        ds, dl = _luts(book)
        nss = stream.gaps.shape[0]
        s_k, c_k, _ = ops.selfsync_sync(
            stream.units, ds, dl, stream.total_bits, nss,
            stream.subseqs_per_seq, book.max_len, early_exit=early_exit)
        s_r, c_r = ref.selfsync_sync(stream.units, ds, dl, stream.total_bits,
                                     nss, stream.subseqs_per_seq,
                                     book.max_len)
        valid = np.asarray(s_r) < int(stream.total_bits)
        assert np.array_equal(np.asarray(s_k)[valid], np.asarray(s_r)[valid])
        assert np.array_equal(np.asarray(c_k), np.asarray(c_r))

    def test_full_pipeline(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=4000)
        from repro.core.huffman import pipeline as pp
        for method in ("gap", "selfsync"):
            out = pp.decode(stream, book, len(syms), method=method,
                            backend="pallas")
            assert np.array_equal(np.asarray(out), syms), method
        out = pp.decode(stream, book, len(syms), method="gap",
                        backend="pallas", strategy="tuned")
        assert np.array_equal(np.asarray(out), syms)


class TestHistogramKernel:
    @pytest.mark.parametrize("nbins", [16, 1024])
    @pytest.mark.parametrize("n", [100, 65536, 70000])
    def test_matches_ref(self, rng, nbins, n):
        x = jnp.asarray(rng.integers(0, nbins, size=n).astype(np.int32))
        assert np.array_equal(np.asarray(ops.histogram(x, nbins)),
                              np.asarray(ref.histogram(x, nbins)))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 3000), st.integers(2, 64), st.integers(0, 2**31))
    def test_property(self, n, nbins, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.integers(0, nbins, size=n).astype(np.int32))
        h = np.asarray(ops.histogram(x, nbins))
        assert h.sum() == n
        assert np.array_equal(h, np.bincount(np.asarray(x),
                                             minlength=nbins))


class TestLorenzoKernels:
    @pytest.mark.parametrize("n", [4096, 8192, 20480])
    @pytest.mark.parametrize("eb", [1e-2, 1e-3])
    def test_quantize_matches_ref(self, rng, n, eb):
        x = jnp.asarray(np.cumsum(rng.standard_normal(n)).astype(np.float32)
                        * 0.1)
        c_k, o_k, r_k = ops.lorenzo_quantize(x, eb)
        c_r, o_r, r_r = ref.lorenzo_quantize(x, eb)
        assert np.array_equal(np.asarray(c_k), np.asarray(c_r))
        assert np.array_equal(np.asarray(o_k), np.asarray(o_r))
        assert np.array_equal(np.asarray(r_k), np.asarray(r_r))

    def test_reconstruct_roundtrip(self, rng):
        n, eb = 8192, 1e-3
        x = np.cumsum(rng.standard_normal(n)).astype(np.float32) * 0.1
        _, _, resid = ops.lorenzo_quantize(jnp.asarray(x), eb)
        xr = ops.lorenzo_reconstruct(resid, eb)
        assert np.abs(np.asarray(xr) - x).max() <= eb + np.spacing(
            np.float32(np.abs(x).max())) * 2

    def test_reconstruct_matches_ref(self, rng):
        n = 12288
        d = jnp.asarray(rng.integers(-3, 4, size=n).astype(np.int32))
        k = ops.lorenzo_reconstruct(d, 1e-3)
        r = ref.lorenzo_reconstruct(d, 1e-3, shape=(n,))
        assert np.allclose(np.asarray(k), np.asarray(r), rtol=1e-6)
