"""Distributed restore: partition helpers, sharded archive round trips,
cross-topology restore, and per-shard salvage (docs/distributed.md).

The multi-device tests force 8 host devices in subprocesses (XLA_FLAGS
must be set before jax imports); partition/layout logic is pure and runs
in-process on whatever devices the suite has.
"""

import json
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import Codec, CodecConfig
from repro.distributed import (ShardedRestorer, ShardedWriter,
                               ShardManifestError, extract_slice,
                               load_manifest, spec_parts, tile_extents,
                               tile_slice)
from repro.launch.mesh import MeshCapacityError, make_host_mesh
from repro.store import format as F

AX = {"data": 4, "model": 2}
_SUB_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}


def _codec():
    return Codec(CodecConfig(eb=1e-3, mode="rel"))


def _run_sub(body: str) -> dict:
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
    """) + textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, timeout=900, env=dict(_SUB_ENV))
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# partition helpers (pure, in-process)
# ---------------------------------------------------------------------------


def test_spec_parts_and_replication_fallback():
    from jax.sharding import PartitionSpec as P
    assert spec_parts(P("data", "model"), (8, 6), AX) == (4, 2)
    # indivisible dims degrade to one part (replicated on that dim)
    assert spec_parts(P("data", "model"), (7, 6), AX) == (1, 2)
    assert spec_parts(P(("data", "model"),), (16,), AX) == (8,)
    assert spec_parts(None, (8, 6), AX) == (1, 1)
    assert spec_parts(P(None, "model"), (8, 6), AX) == (1, 2)
    # an axis the mesh does not have is a spec bug, not silent replication
    with pytest.raises(ValueError, match="not in mesh axes"):
        spec_parts(P("tp"), (8,), AX)


def test_tile_extents_cover_exactly():
    shape, parts = (8, 6), (4, 2)
    x = np.arange(48, dtype=np.float32).reshape(shape)
    seen = np.zeros(shape, dtype=int)
    tiles = {}
    for index, offset, tshape in tile_extents(shape, parts):
        seen[tile_slice(offset, tshape)] += 1
        tiles[(offset, tshape)] = x[tile_slice(offset, tshape)]
    assert (seen == 1).all()                       # exact cover, no overlap
    full = extract_slice(tuple(slice(0, n) for n in shape), tiles,
                         np.float32, shape)
    np.testing.assert_array_equal(full, x)
    # arbitrary cross-tile slice reassembles correctly
    sl = (slice(1, 7), slice(2, 6))
    np.testing.assert_array_equal(
        extract_slice(sl, tiles, np.float32, shape), x[sl])
    # incomplete coverage is an error, not silent garbage
    some = dict(list(tiles.items())[:2])
    with pytest.raises(ValueError, match="cover"):
        extract_slice(tuple(slice(0, n) for n in shape), some,
                      np.float32, shape)


def test_fit_degrades_to_replication():
    from repro.runtime.sharding import _fit
    mesh = SimpleNamespace(shape=AX)
    assert _fit(mesh, 8, "model") == "model"
    assert _fit(mesh, 7, "model") is None          # 7 % 2 -> replicate
    assert _fit(mesh, 16, ("data", "model")) == ("data", "model")
    assert _fit(mesh, 12, ("data", "model")) is None   # 12 % 8 -> replicate
    assert _fit(mesh, 8, None) is None


# ---------------------------------------------------------------------------
# sharded archive layout (in-process; layout needs no devices)
# ---------------------------------------------------------------------------


def test_sharded_round_trip_and_manifest(tmp_path):
    from jax.sharding import PartitionSpec as P
    codec = _codec()
    rng = np.random.default_rng(0)
    big = rng.normal(size=(64, 32)).astype(np.float32)
    rep = rng.normal(size=(16, 8)).astype(np.float32)
    d = str(tmp_path / "arc")
    with ShardedWriter(d, AX, codec=codec, n_shards=3) as sw:
        sw.add("a.big", big, P("data", "model"))
        sw.add("a.rep", rep)                       # replicated single tile
        with pytest.raises(F.StoreError, match="duplicate"):
            sw.add("a.big", big)
    man = load_manifest(d)
    assert man["version"] == F.SHARD_MANIFEST_VERSION
    assert man["n_shards"] == 3
    assert man["entries"]["a.big"]["parts"] == [4, 2]
    assert len(man["entries"]["a.big"]["tiles"]) == 8
    assert len(man["entries"]["a.rep"]["tiles"]) == 1
    shards = {t["shard"] for t in man["entries"]["a.big"]["tiles"]}
    assert shards == {0, 1, 2}                     # tiles spread over shards

    r = ShardedRestorer(d, codec=codec)
    out = r.restore()
    bound = 1e-3 * (big.max() - big.min()) * 1.0001
    assert np.abs(np.asarray(out["a.big"]) - big).max() <= bound
    # repeat restore is bit-exact (deterministic decode)
    out2 = ShardedRestorer(d, codec=codec).restore()
    np.testing.assert_array_equal(np.asarray(out["a.big"]),
                                  np.asarray(out2["a.big"]))
    np.testing.assert_array_equal(np.asarray(out["a.rep"]),
                                  np.asarray(out2["a.rep"]))


def test_manifest_failure_modes(tmp_path):
    d = str(tmp_path / "arc")
    with pytest.raises(ShardManifestError, match="missing"):
        load_manifest(d)
    os.makedirs(d)
    mpath = os.path.join(d, F.SHARD_MANIFEST_NAME)
    with open(mpath, "w") as f:
        f.write('{"version": 1, "entr')             # torn half-write
    with pytest.raises(ShardManifestError, match="torn"):
        load_manifest(d)
    with open(mpath, "w") as f:
        json.dump({"version": F.SHARD_MANIFEST_VERSION + 1,
                   "entries": {}}, f)
    with pytest.raises(ShardManifestError, match="newer"):
        load_manifest(d)
    with open(mpath, "w") as f:
        json.dump({"version": 1, "entries": {"x": {"tiles": "nope"}}}, f)
    with pytest.raises(ShardManifestError, match="invalid"):
        load_manifest(d)


def test_corrupt_shard_quarantines_only_its_entries(tmp_path):
    from jax.sharding import PartitionSpec as P
    codec = _codec()
    rng = np.random.default_rng(1)
    d = str(tmp_path / "arc")
    xs = {f"t{i}": rng.normal(size=(32, 16)).astype(np.float32)
          for i in range(3)}
    with ShardedWriter(d, {"data": 2}, codec=codec, n_shards=2) as sw:
        for name, x in xs.items():
            sw.add(name, x, P("data"))
    # trash shard 1 wholesale; every entry has one tile in each shard here,
    # so under "raise" the failure must name the shard file
    path = os.path.join(d, F.shard_filename(1))
    sz = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.write(b"\xff" * sz)
    r = ShardedRestorer(d, codec=codec)
    with pytest.raises(F.StoreError, match="shard_00001.szt"):
        r.restore(policy="raise")
    reasons = {}
    out = r.restore(policy="skip",
                    on_error=lambda n, e: reasons.setdefault(n, str(e)))
    assert out == {}                               # all entries span shard 1
    assert all("shard_00001.szt" in why for why in reasons.values())
    # a missing shard file behaves the same, and intact entries survive
    os.remove(path)
    with pytest.raises(F.StoreError, match="missing"):
        r = ShardedRestorer(d, codec=codec)
        r.restore(policy="raise")


def test_missing_shard_spares_other_entries(tmp_path):
    from jax.sharding import PartitionSpec as P
    codec = _codec()
    rng = np.random.default_rng(2)
    d = str(tmp_path / "arc")
    a = rng.normal(size=(32, 16)).astype(np.float32)
    b = rng.normal(size=(32, 16)).astype(np.float32)
    with ShardedWriter(d, {"data": 2}, codec=codec, n_shards=2) as sw:
        sw.add("a", a, P("data"))
        sw.add("b", b)                             # single tile -> shard 0
    os.remove(os.path.join(d, F.shard_filename(1)))
    reasons = {}
    out = ShardedRestorer(d, codec=codec).restore(
        policy="skip", on_error=lambda n, e: reasons.setdefault(n, str(e)))
    assert set(reasons) == {"a"} and "shard_00001.szt" in reasons["a"]
    np.testing.assert_array_equal(  # b lives wholly in shard 0: bit-intact
        np.asarray(out["b"]),
        np.asarray(ShardedRestorer(d, codec=codec).restore(names=["b"])["b"]))


def test_decompress_tree_shardings():
    import jax
    codec = _codec()
    rng = np.random.default_rng(3)
    tree = {"w": rng.normal(size=(4096,)).astype(np.float32)}
    comp = codec.compress_tree(tree, min_size=1024)
    with pytest.raises(ValueError, match="shardings"):
        codec.decompress_tree(comp, shardings={"w": None, "x": None})
    dev = jax.devices()[0]
    s = jax.sharding.SingleDeviceSharding(dev)
    out = codec.decompress_tree(comp, shardings={"w": s})
    assert out["w"].sharding.is_equivalent_to(s, 1)


def test_make_host_mesh_capacity_errors():
    import jax
    n = len(jax.devices())
    with pytest.raises(MeshCapacityError, match=">= 1"):
        make_host_mesh(model=0)
    with pytest.raises(MeshCapacityError,
                       match=f"model={n + 1}.*{n} device"):
        make_host_mesh(model=n + 1)
    with pytest.raises(MeshCapacityError, match=f"needs {2 * n}"):
        make_host_mesh(data=2 * n, model=1)
    mesh = make_host_mesh()
    assert mesh.shape["data"] == n and mesh.shape["model"] == 1


# ---------------------------------------------------------------------------
# multi-device (forced 8-device subprocesses)
# ---------------------------------------------------------------------------


def test_param_shardings_lay_out_configs_on_8_devices():
    out = _run_sub("""
        import jax
        from repro import configs
        from repro.models import transformer as T
        from repro.runtime import sharding as shd
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(4, 2)
        report = {}
        for arch in ("deepseek-v3-671b", "qwen2.5-3b"):
            cfg = configs.get_config(arch).reduced()
            ps = jax.eval_shape(
                lambda c=cfg: T.init_model(jax.random.PRNGKey(0), c))
            shards = shd.param_shardings(ps, mesh)
            bad = []

            def check(kp, x, s):
                spec = s.spec
                for i, ax in enumerate(spec):
                    if ax is None:
                        continue
                    n = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        n *= mesh.shape[a]
                    if x.shape[i] % n != 0:
                        bad.append([str(kp), list(x.shape), str(spec)])

            jax.tree_util.tree_map_with_path(check, ps, shards)
            n_sharded = sum(
                any(ax is not None for ax in s.spec)
                for s in jax.tree.leaves(
                    shards, is_leaf=lambda x: hasattr(x, "spec")))
            report[arch] = {"bad": bad[:5], "n_bad": len(bad),
                            "n_sharded": n_sharded}
        print(json.dumps(report))
    """)
    for arch, rep in out.items():
        assert rep["n_bad"] == 0, (arch, rep["bad"])
        assert rep["n_sharded"] > 0, arch          # rules actually fire


def test_cross_topology_restore_bit_exact():
    """Checkpoint written on a (4,2) mesh restores bit-exact on (2,4) and
    single-device, landing directly in the target shardings."""
    out = _run_sub("""
        import numpy as np, jax, tempfile, glob, os
        from repro.core import Codec, CodecConfig
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.mesh import make_host_mesh

        codec = Codec(CodecConfig(eb=1e-3, mode="rel"))
        rng = np.random.default_rng(0)
        params = {
            "layers": {"0": {
                "attn": {"wq": rng.normal(size=(256, 512))
                         .astype(np.float32)},
                "mlp": {"wg": rng.normal(size=(256, 1024))
                        .astype(np.float32)}}},
            "norm": rng.normal(size=(64,)).astype(np.float32)}
        res = {}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, codec=codec, compress_min_size=4096)
            mgr.save(1, params, mesh=make_host_mesh(4, 2), shard_count=4)
            sd = os.path.join(d, "step_00000001")
            res["shard_files"] = len(
                glob.glob(os.path.join(sd, "shard_*.szt")))

            o24 = mgr.restore(1, mesh=make_host_mesh(2, 4))
            wq = o24["params"]["layers"]["0"]["attn"]["wq"]
            res["addressable"] = len(wq.addressable_shards)
            res["local_shape"] = list(wq.addressable_shards[0].data.shape)
            res["n_dev"] = len(wq.sharding.device_set)

            o1 = mgr.restore(1)                    # single-device assembly
            res["bit_exact_24_vs_1"] = bool(np.array_equal(
                np.asarray(wq),
                np.asarray(o1["params"]["layers"]["0"]["attn"]["wq"])))
            res["norm_exact"] = bool(np.array_equal(
                np.asarray(o24["params"]["norm"]),
                np.asarray(o1["params"]["norm"])))
            mx = float(np.abs(np.asarray(o1["params"]["layers"]["0"]
                       ["attn"]["wq"]) - params["layers"]["0"]["attn"]
                       ["wq"]).max())
            rg = params["layers"]["0"]["attn"]["wq"]
            res["within_eb"] = mx <= 1e-3 * float(rg.max() - rg.min()) * 1.01
        print(json.dumps(res))
    """)
    assert out["shard_files"] == 4
    assert out["addressable"] == 8 and out["n_dev"] == 8
    assert out["local_shape"] == [128, 128]        # (2,4) mesh slice, no
    assert out["bit_exact_24_vs_1"]                # device-0 gather
    assert out["norm_exact"]
    assert out["within_eb"]


def test_corrupted_shard_salvage_on_8_devices():
    out = _run_sub("""
        import numpy as np, jax, tempfile, os
        from repro.core import Codec, CodecConfig
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.mesh import make_host_mesh

        codec = Codec(CodecConfig(eb=1e-3, mode="rel"))
        rng = np.random.default_rng(0)
        params = {
            "wq": rng.normal(size=(256, 512)).astype(np.float32),
            "wg": rng.normal(size=(256, 1024)).astype(np.float32),
            "norm": rng.normal(size=(64,)).astype(np.float32)}
        res = {}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, codec=codec, compress_min_size=4096)
            mesh = make_host_mesh(4, 2)
            mgr.save(1, params, mesh=mesh, shard_count=4)
            sd = os.path.join(d, "step_00000001")
            path = os.path.join(sd, "shard_00001.szt")
            sz = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.seek(sz // 2); f.write(b"\\xff" * 4096)

            try:
                mgr.restore(1, policy="raise", mesh=mesh)
                res["raise_named"] = False
            except Exception as e:
                res["raise_named"] = "shard_00001.szt" in str(e)
            o = mgr.restore(1, policy="zero_fill", mesh=mesh)
            res["quarantined"] = sorted(o["quarantined"])
            res["reasons_name_shard"] = all(
                "shard_00001.szt" in why
                for why in o["quarantined"].values())
            intact = [k.split(".", 1)[1] for k in
                      ("params.wq", "params.wg", "params.norm")
                      if k not in o["quarantined"]]
            res["intact"] = intact
            res["intact_restored"] = all(
                np.abs(np.asarray(o["params"][k])).max() > 0
                for k in intact)
            res["zero_filled"] = all(
                float(np.abs(np.asarray(
                    o["params"][k.split(".", 1)[1]])).max()) == 0.0
                for k in o["quarantined"])
        print(json.dumps(res))
    """)
    assert out["raise_named"]
    assert out["quarantined"], "corruption must quarantine something"
    assert out["reasons_name_shard"]
    assert out["intact"], "other entries must survive"
    assert out["intact_restored"]
    assert out["zero_filled"]
