"""Fault tolerance: heartbeats, stragglers, elastic re-mesh, preemption."""

import subprocess
import sys

import pytest

from repro.runtime import fault_tolerance as ft
from repro.data.pipeline import DataConfig, SyntheticLM

import numpy as np


class TestHeartbeat:
    def test_dead_detection(self):
        t = [0.0]
        mon = ft.HeartbeatMonitor(["a", "b"], timeout=10,
                                  clock=lambda: t[0])
        mon.beat("a")
        t[0] = 15.0
        mon.beat("b")
        assert mon.dead() == ["a"]

    def test_straggler_detection(self):
        mon = ft.HeartbeatMonitor(["w0", "w1", "w2", "w3"], timeout=1e9)
        for i in range(8):
            for w in ("w0", "w1", "w2"):
                mon.beat(w, step_time=1.0)
            mon.beat("w3", step_time=5.0)
        s = ft.StragglerMitigator(factor=2.0)
        assert s.stragglers(mon) == ["w3"]


class TestElastic:
    def test_remesh_shrinks_data_axis(self):
        assert ft.plan_elastic_remesh(512) == (32, 16)
        assert ft.plan_elastic_remesh(511) == (16, 16)
        assert ft.plan_elastic_remesh(256) == (16, 16)
        assert ft.plan_elastic_remesh(255) == (8, 16)
        assert ft.plan_elastic_remesh(15) is None

    def test_shard_reassign(self):
        m = ft.reassign_shards(8, dead=[2, 5])
        assert set(m) == {2, 5}
        assert all(v not in (2, 5) for v in m.values())

    def test_skip_ahead_data_identical(self):
        """Reassigned worker reproduces the dead worker's batches exactly."""
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, n_shards=4,
                         shard_id=2)
        a = SyntheticLM(cfg).batch_at(11)
        b = SyntheticLM(cfg).batch_at(11)  # fresh instance, same shard id
        assert np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


@pytest.mark.slow
class TestPreemption:
    def test_preempt_and_resume(self, tmp_path):
        """train.py exits mid-run (simulated preemption); rerunning resumes
        from the checkpoint and finishes."""
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "qwen3-0.6b", "--reduced", "--steps", "8",
                "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "2", "--log-every", "2"]
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
        p1 = subprocess.run(base + ["--preempt-at", "4"],
                            capture_output=True, text=True, timeout=900,
                            env=env)
        assert p1.returncode == 17, p1.stderr[-1500:]
        assert "simulated preemption" in p1.stdout

        p2 = subprocess.run(base, capture_output=True, text=True,
                            timeout=900, env=env)
        assert p2.returncode == 0, p2.stderr[-1500:]
        assert "resumed from step" in p2.stdout
        assert "done:" in p2.stdout
