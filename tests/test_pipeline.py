"""Plan/execute pipeline: backend parity matrix + batched multi-tensor decode.

The matrix asserts the single ``pipeline.decode`` entry point is bit-exact
against the sequential oracle for every {method} x {backend} x {strategy}
cell; the batch tests assert ``decode_batch`` is byte-identical to
per-tensor decoding while issuing at most one decode-write dispatch per CR
class across ALL tensors.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.huffman import decode as hd
from repro.core.huffman import pipeline as pp

from conftest import make_book_and_stream


def _oracle(book, stream, n):
    return np.asarray(hd.decode_sequential(
        jnp.asarray(stream.units), jnp.asarray(book.dec_sym),
        jnp.asarray(book.dec_len), n_symbols=n, max_len=book.max_len))


class TestDecodeParityMatrix:
    @pytest.mark.parametrize("method", ["gap", "selfsync"])
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("strategy,tile_syms",
                             [("tuned", None), ("tile", 1024), ("tile", 4096)])
    def test_matches_sequential(self, rng, method, backend, strategy,
                                tile_syms):
        book, syms, stream = make_book_and_stream(rng, n_syms=4500)
        kwargs = {} if tile_syms is None else {"tile_syms": tile_syms}
        out = pp.decode(stream, book, len(syms), method=method,
                        backend=backend, strategy=strategy, **kwargs)
        assert np.array_equal(np.asarray(out), syms)
        assert np.array_equal(_oracle(book, stream, len(syms)), syms)

    def test_padded_baseline(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=3000)
        for backend in ("ref", "pallas"):
            out = pp.decode(stream, book, len(syms), method="gap",
                            backend=backend, strategy="padded")
            assert np.array_equal(np.asarray(out), syms), backend

    def test_plan_is_backend_portable(self, rng):
        """A plan built on one backend executes exactly on the other."""
        book, syms, stream = make_book_and_stream(rng, n_syms=3000)
        plan = pp.build_plan(stream, book, method="gap", backend="ref")
        out = pp.decode(stream, book, len(syms), plan=plan, backend="pallas",
                        strategy="tuned")
        assert np.array_equal(np.asarray(out), syms)

    def test_unknown_backend_and_strategy(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=500)
        with pytest.raises(ValueError):
            pp.decode(stream, book, len(syms), backend="no_such_backend")
        with pytest.raises(ValueError):
            pp.decode(stream, book, len(syms), strategy="no_such_strategy")


class TestPlan:
    def test_plan_offsets_partition_output(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=6000)
        for method in ("gap", "selfsync"):
            plan = pp.build_plan(stream, book, method=method)
            assert int(plan.offsets[-1]) == len(syms)
            assert int(plan.seq_counts.sum()) == len(syms)
            n_seq = stream.n_seq
            assert sorted(plan.classes.seq_order.tolist()) == list(range(n_seq))

    def test_ss_max_single_source(self):
        """The audited helper matches the codebook's min-starts bound."""
        from repro.core.huffman.codebook import Codebook
        from repro.core.huffman.bits import SUBSEQ_BITS

        for max_len in (8, 10, 12):
            for tile in (1024, 3584, 4096, 8192):
                book = Codebook(n_symbols=2, max_len=max_len,
                                enc_code=np.zeros(2, np.uint32),
                                enc_len=np.full(2, max_len, np.uint8),
                                dec_sym=np.zeros(1 << max_len, np.uint16),
                                dec_len=np.full(1 << max_len, max_len,
                                                np.uint8))
                expect = tile // book.min_starts_per_subseq(SUBSEQ_BITS) + 2
                assert pp.ss_max_for_tile(tile, max_len) == expect


class TestDecodeBatch:
    def _make_items(self, rng, specs):
        items = []
        for n, max_len, zipf in specs:
            book, syms, stream = make_book_and_stream(
                rng, n_syms=n, max_len=max_len, zipf=zipf)
            items.append((stream, book, syms))
        return items

    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    def test_byte_identical_to_per_tensor(self, rng, backend):
        # >= 4 tensors, heterogeneous sizes AND codebook widths (max_len).
        items = self._make_items(rng, [(5000, 12, 1.4), (2000, 10, 1.2),
                                       (6001, 12, 2.0), (900, 11, 1.6),
                                       (260, 12, 1.3)])
        streams = [s for s, _, _ in items]
        books = [b for _, b, _ in items]
        n_outs = [len(y) for _, _, y in items]
        outs = pp.decode_batch(streams, books, n_outs, backend=backend)
        for (stream, book, syms), out in zip(items, outs):
            per_tensor = pp.decode(stream, book, len(syms), backend=backend,
                                   strategy="tuned")
            assert np.asarray(out).tobytes() == np.asarray(
                per_tensor).tobytes()
            assert np.array_equal(np.asarray(out), syms)

    def test_one_dispatch_per_class(self, rng):
        """The registry counter proves class-merged dispatch: N tensors cost
        at most one decode-write launch per CR class, not N x classes."""
        items = self._make_items(rng, [(4000, 12, 1.4)] * 4)
        streams = [s for s, _, _ in items]
        books = [b for _, b, _ in items]
        n_outs = [len(y) for _, _, y in items]
        plans = [pp.build_plan(s, b) for s, b, _ in items]
        classes_present = set()
        for plan in plans:
            classes_present |= {int(c) for c in plan.classes.classes}

        be = pp.get_backend("ref")
        be.reset_stats()
        outs = pp.decode_batch(streams, books, n_outs, plans=plans)
        batched = be.stats["decode_write_dispatches"]
        assert batched <= len(classes_present)
        assert batched <= plans[0].t_high + 1

        be.reset_stats()
        for (s, b, y), plan in zip(items, plans):
            pp.decode(s, b, len(y), plan=plan, strategy="tuned")
        per_tensor = be.stats["decode_write_dispatches"]
        assert batched < per_tensor  # the whole point of the batch path
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)

    def test_tail_padding_sequences(self, rng):
        """Regression: tensors whose final sequence is mostly zero padding.

        Each such sequence lands in a low-CR class with many count-0
        subsequences; gathered across tensors, a single output tile used to
        span more subsequences than ``ss_max`` provisioned, silently
        zeroing the symbols past the lane budget (caught restoring a real
        checkpoint whose optimizer-moment shard decoded corrupt)."""
        items = []
        k = 0
        while len(items) < 6 and k < 64:
            book, syms, stream = make_book_and_stream(
                rng, n_syms=17000 + 9 * k, zipf=1.15)
            k += 1
            plan = pp.build_plan(stream, book)
            if plan.classes.classes[-1] <= 2 and plan.seq_counts[-1] < 200:
                items.append((stream, book, syms))
        assert len(items) >= 4, "could not construct tail-padded streams"
        outs = pp.decode_batch([s for s, _, _ in items],
                               [b for _, b, _ in items],
                               [len(y) for _, _, y in items])
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)

    def test_oversized_batch_chunks(self, rng):
        """Batches past the int32 bit budget split transparently."""
        items = self._make_items(rng, [(2000, 12, 1.4)] * 4)
        streams = [s for s, _, _ in items]
        bits0 = int(streams[0].units.shape[0]) * 32
        old = pp.MAX_BATCH_BITS
        pp.MAX_BATCH_BITS = bits0 + 1   # at most one stream per sub-batch
        try:
            outs = pp.decode_batch(streams, [b for _, b, _ in items],
                                   [len(y) for _, _, y in items])
            # A single stream over the budget is the base case, not an
            # infinite split (regression: RecursionError).
            pp.MAX_BATCH_BITS = bits0 // 2
            solo = pp.decode_batch(streams[:1], [items[0][1]],
                                   [len(items[0][2])])
        finally:
            pp.MAX_BATCH_BITS = old
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)
        assert np.array_equal(np.asarray(solo[0]), items[0][2])

    def test_selfsync_batch(self, rng):
        items = self._make_items(rng, [(3000, 12, 1.4), (1200, 12, 1.8),
                                       (2500, 11, 1.3), (800, 12, 1.5)])
        outs = pp.decode_batch([s for s, _, _ in items],
                               [b for _, b, _ in items],
                               [len(y) for _, _, y in items],
                               method="selfsync")
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)

    def test_empty_batch(self):
        assert pp.decode_batch([], [], []) == []


class TestFusedDecode:
    """``decode(transform=...)`` fuses dequantize+reconstruct into phase 4;
    output must be bit-exact with decoding the codes and then running
    ``lorenzo.dequantize`` (the two-pass path)."""

    RADIUS = 512

    def _transform_and_oracle(self, syms):
        from repro.core.sz import lorenzo

        n = len(syms)
        opos = np.full(8, -1, np.int32)
        oval = np.zeros(8, np.int32)
        opos[:3] = [1, n // 2, n - 1]
        oval[:3] = [700, -900, 1500]
        eb = 1e-3
        tr = pp.OutputTransform(eb=eb, radius=self.RADIUS,
                                outlier_pos=jnp.asarray(opos),
                                outlier_val=jnp.asarray(oval))
        oracle = lorenzo.dequantize(jnp.asarray(syms), jnp.asarray(opos),
                                    jnp.asarray(oval), eb, (n,),
                                    radius=self.RADIUS)
        return tr, np.asarray(oracle)

    @pytest.mark.parametrize("method", ["gap", "selfsync"])
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    @pytest.mark.parametrize("strategy", ["tile", "padded"])
    def test_matches_two_pass(self, rng, method, backend, strategy):
        book, syms, stream = make_book_and_stream(rng, n_syms=4500)
        tr, oracle = self._transform_and_oracle(syms)
        out = pp.decode(stream, book, len(syms), method=method,
                        backend=backend, strategy=strategy, transform=tr)
        assert out.dtype == jnp.float32
        assert np.asarray(out).tobytes() == oracle.tobytes()

    def test_fused_dispatches_counted(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=2000)
        tr, _ = self._transform_and_oracle(syms)
        be = pp.get_backend("ref")
        be.reset_stats()
        pp.decode(stream, book, len(syms), strategy="tile", transform=tr)
        assert be.stats["fused_dispatches"] == 1
        assert be.stats["decode_write_dispatches"] == 1

    def test_tuned_transform_raises(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=1000)
        tr, _ = self._transform_and_oracle(syms)
        with pytest.raises(ValueError, match="tuned"):
            pp.decode(stream, book, len(syms), strategy="tuned",
                      transform=tr)

    def test_backend_without_fused_ops_raises(self, rng):
        """decode(transform=) on a fused-less backend is a hard error; the
        silent fallback (+ counter) lives one level up, in
        ``sz.compressor.decompress``."""
        book, syms, stream = make_book_and_stream(rng, n_syms=1000)
        tr, _ = self._transform_and_oracle(syms)
        ref = pp.get_backend("ref")
        bare = pp.DecodeBackend(name="bare", count_fn=ref.count_fn,
                                sync_fn=ref.sync_fn, tiles_fn=ref.tiles_fn,
                                padded_fn=ref.padded_fn)
        assert not bare.supports_fused
        with pytest.raises(ValueError, match="fused"):
            pp.decode(stream, book, len(syms), backend=bare,
                      strategy="tile", transform=tr)


class TestDecompressBatch:
    def test_matches_per_tensor_decompress(self, rng):
        from repro.core import api
        from repro.data.pipeline import smooth_field

        cs = [api.compress(smooth_field((40, 30 + 11 * i), seed=i), eb=1e-3)
              for i in range(4)]
        outs = api.decompress_batch(cs)
        for c, out in zip(cs, outs):
            ref = np.asarray(api.decompress(c, strategy="tuned"))
            assert np.asarray(out).tobytes() == ref.tobytes()
