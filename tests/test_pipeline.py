"""Plan/execute pipeline: backend parity matrix + batched multi-tensor decode.

The matrix asserts the single ``pipeline.decode`` entry point is bit-exact
against the sequential oracle for every {method} x {backend} x {strategy}
cell; the batch tests assert ``decode_batch`` is byte-identical to
per-tensor decoding while issuing at most one decode-write dispatch per CR
class across ALL tensors.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.huffman import decode as hd
from repro.core.huffman import pipeline as pp

from conftest import make_book_and_stream


def _oracle(book, stream, n):
    return np.asarray(hd.decode_sequential(
        jnp.asarray(stream.units), jnp.asarray(book.dec_sym),
        jnp.asarray(book.dec_len), n_symbols=n, max_len=book.max_len))


class TestDecodeParityMatrix:
    @pytest.mark.parametrize("method", ["gap", "selfsync"])
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("strategy,tile_syms",
                             [("tuned", None), ("tile", 1024), ("tile", 4096)])
    def test_matches_sequential(self, rng, method, backend, strategy,
                                tile_syms):
        book, syms, stream = make_book_and_stream(rng, n_syms=4500)
        kwargs = {} if tile_syms is None else {"tile_syms": tile_syms}
        out = pp.decode(stream, book, len(syms), method=method,
                        backend=backend, strategy=strategy, **kwargs)
        assert np.array_equal(np.asarray(out), syms)
        assert np.array_equal(_oracle(book, stream, len(syms)), syms)

    def test_padded_baseline(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=3000)
        for backend in ("ref", "pallas"):
            out = pp.decode(stream, book, len(syms), method="gap",
                            backend=backend, strategy="padded")
            assert np.array_equal(np.asarray(out), syms), backend

    def test_plan_is_backend_portable(self, rng):
        """A plan built on one backend executes exactly on the other."""
        book, syms, stream = make_book_and_stream(rng, n_syms=3000)
        plan = pp.build_plan(stream, book, method="gap", backend="ref")
        out = pp.decode(stream, book, len(syms), plan=plan, backend="pallas",
                        strategy="tuned")
        assert np.array_equal(np.asarray(out), syms)

    def test_unknown_backend_and_strategy(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=500)
        with pytest.raises(ValueError):
            pp.decode(stream, book, len(syms), backend="no_such_backend")
        with pytest.raises(ValueError):
            pp.decode(stream, book, len(syms), strategy="no_such_strategy")


class TestPlan:
    def test_plan_offsets_partition_output(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=6000)
        for method in ("gap", "selfsync"):
            plan = pp.build_plan(stream, book, method=method)
            assert int(plan.offsets[-1]) == len(syms)
            assert int(plan.seq_counts.sum()) == len(syms)
            n_seq = stream.n_seq
            assert sorted(plan.classes.seq_order.tolist()) == list(range(n_seq))

    def test_ss_max_single_source(self):
        """The audited helper matches the codebook's min-starts bound."""
        from repro.core.huffman.codebook import Codebook
        from repro.core.huffman.bits import SUBSEQ_BITS

        for max_len in (8, 10, 12):
            for tile in (1024, 3584, 4096, 8192):
                book = Codebook(n_symbols=2, max_len=max_len,
                                enc_code=np.zeros(2, np.uint32),
                                enc_len=np.full(2, max_len, np.uint8),
                                dec_sym=np.zeros(1 << max_len, np.uint16),
                                dec_len=np.full(1 << max_len, max_len,
                                                np.uint8))
                expect = tile // book.min_starts_per_subseq(SUBSEQ_BITS) + 2
                assert pp.ss_max_for_tile(tile, max_len) == expect


class TestDecodeBatch:
    def _make_items(self, rng, specs):
        items = []
        for n, max_len, zipf in specs:
            book, syms, stream = make_book_and_stream(
                rng, n_syms=n, max_len=max_len, zipf=zipf)
            items.append((stream, book, syms))
        return items

    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    def test_byte_identical_to_per_tensor(self, rng, backend):
        # >= 4 tensors, heterogeneous sizes AND codebook widths (max_len).
        items = self._make_items(rng, [(5000, 12, 1.4), (2000, 10, 1.2),
                                       (6001, 12, 2.0), (900, 11, 1.6),
                                       (260, 12, 1.3)])
        streams = [s for s, _, _ in items]
        books = [b for _, b, _ in items]
        n_outs = [len(y) for _, _, y in items]
        outs = pp.decode_batch(streams, books, n_outs, backend=backend)
        for (stream, book, syms), out in zip(items, outs):
            per_tensor = pp.decode(stream, book, len(syms), backend=backend,
                                   strategy="tuned")
            assert np.asarray(out).tobytes() == np.asarray(
                per_tensor).tobytes()
            assert np.array_equal(np.asarray(out), syms)

    def test_one_dispatch_per_class(self, rng):
        """The registry counter proves class-merged dispatch: N tensors cost
        at most one decode-write launch per CR class, not N x classes."""
        items = self._make_items(rng, [(4000, 12, 1.4)] * 4)
        streams = [s for s, _, _ in items]
        books = [b for _, b, _ in items]
        n_outs = [len(y) for _, _, y in items]
        plans = [pp.build_plan(s, b) for s, b, _ in items]
        classes_present = set()
        for plan in plans:
            classes_present |= {int(c) for c in plan.classes.classes}

        be = pp.get_backend("ref")
        be.reset_stats()
        outs = pp.decode_batch(streams, books, n_outs, plans=plans)
        batched = be.stats["decode_write_dispatches"]
        assert batched <= len(classes_present)
        assert batched <= plans[0].t_high + 1

        be.reset_stats()
        for (s, b, y), plan in zip(items, plans):
            pp.decode(s, b, len(y), plan=plan, strategy="tuned")
        per_tensor = be.stats["decode_write_dispatches"]
        assert batched < per_tensor  # the whole point of the batch path
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)

    def test_tail_padding_sequences(self, rng):
        """Regression: tensors whose final sequence is mostly zero padding.

        Each such sequence lands in a low-CR class with many count-0
        subsequences; gathered across tensors, a single output tile used to
        span more subsequences than ``ss_max`` provisioned, silently
        zeroing the symbols past the lane budget (caught restoring a real
        checkpoint whose optimizer-moment shard decoded corrupt)."""
        items = []
        k = 0
        while len(items) < 6 and k < 64:
            book, syms, stream = make_book_and_stream(
                rng, n_syms=17000 + 9 * k, zipf=1.15)
            k += 1
            plan = pp.build_plan(stream, book)
            if plan.classes.classes[-1] <= 2 and plan.seq_counts[-1] < 200:
                items.append((stream, book, syms))
        assert len(items) >= 4, "could not construct tail-padded streams"
        outs = pp.decode_batch([s for s, _, _ in items],
                               [b for _, b, _ in items],
                               [len(y) for _, _, y in items])
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)

    def test_oversized_batch_chunks(self, rng):
        """Batches past the int32 bit budget split transparently."""
        items = self._make_items(rng, [(2000, 12, 1.4)] * 4)
        streams = [s for s, _, _ in items]
        bits0 = int(streams[0].units.shape[0]) * 32
        old = pp.MAX_BATCH_BITS
        pp.MAX_BATCH_BITS = bits0 + 1   # at most one stream per sub-batch
        try:
            outs = pp.decode_batch(streams, [b for _, b, _ in items],
                                   [len(y) for _, _, y in items])
            # A single stream over the budget is the base case, not an
            # infinite split (regression: RecursionError).
            pp.MAX_BATCH_BITS = bits0 // 2
            solo = pp.decode_batch(streams[:1], [items[0][1]],
                                   [len(items[0][2])])
        finally:
            pp.MAX_BATCH_BITS = old
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)
        assert np.array_equal(np.asarray(solo[0]), items[0][2])

    def test_selfsync_batch(self, rng):
        items = self._make_items(rng, [(3000, 12, 1.4), (1200, 12, 1.8),
                                       (2500, 11, 1.3), (800, 12, 1.5)])
        outs = pp.decode_batch([s for s, _, _ in items],
                               [b for _, b, _ in items],
                               [len(y) for _, _, y in items],
                               method="selfsync")
        for (_, _, syms), out in zip(items, outs):
            assert np.array_equal(np.asarray(out), syms)

    def test_empty_batch(self):
        assert pp.decode_batch([], [], []) == []


class TestFusedDecode:
    """``decode(transform=...)`` fuses dequantize+reconstruct into phase 4;
    output must be bit-exact with decoding the codes and then running
    ``lorenzo.dequantize`` (the two-pass path)."""

    RADIUS = 512

    def _transform_and_oracle(self, syms):
        from repro.core.sz import lorenzo

        n = len(syms)
        opos = np.full(8, -1, np.int32)
        oval = np.zeros(8, np.int32)
        opos[:3] = [1, n // 2, n - 1]
        oval[:3] = [700, -900, 1500]
        eb = 1e-3
        tr = pp.OutputTransform(eb=eb, radius=self.RADIUS,
                                outlier_pos=jnp.asarray(opos),
                                outlier_val=jnp.asarray(oval))
        oracle = lorenzo.dequantize(jnp.asarray(syms), jnp.asarray(opos),
                                    jnp.asarray(oval), eb, (n,),
                                    radius=self.RADIUS)
        return tr, np.asarray(oracle)

    @pytest.mark.parametrize("method", ["gap", "selfsync"])
    @pytest.mark.parametrize(
        "backend",
        ["ref", pytest.param("pallas", marks=pytest.mark.slow)])
    @pytest.mark.parametrize("strategy", ["tile", "padded"])
    def test_matches_two_pass(self, rng, method, backend, strategy):
        book, syms, stream = make_book_and_stream(rng, n_syms=4500)
        tr, oracle = self._transform_and_oracle(syms)
        out = pp.decode(stream, book, len(syms), method=method,
                        backend=backend, strategy=strategy, transform=tr)
        assert out.dtype == jnp.float32
        assert np.asarray(out).tobytes() == oracle.tobytes()

    def test_fused_dispatches_counted(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=2000)
        tr, _ = self._transform_and_oracle(syms)
        be = pp.get_backend("ref")
        be.reset_stats()
        pp.decode(stream, book, len(syms), strategy="tile", transform=tr)
        assert be.stats["fused_dispatches"] == 1
        assert be.stats["decode_write_dispatches"] == 1

    def test_tuned_transform_raises(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=1000)
        tr, _ = self._transform_and_oracle(syms)
        with pytest.raises(ValueError, match="tuned"):
            pp.decode(stream, book, len(syms), strategy="tuned",
                      transform=tr)

    def test_backend_without_fused_ops_raises(self, rng):
        """decode(transform=) on a fused-less backend is a hard error; the
        silent fallback (+ counter) lives one level up, in
        ``sz.compressor.decompress``."""
        book, syms, stream = make_book_and_stream(rng, n_syms=1000)
        tr, _ = self._transform_and_oracle(syms)
        ref = pp.get_backend("ref")
        bare = pp.DecodeBackend(name="bare", count_fn=ref.count_fn,
                                sync_fn=ref.sync_fn, tiles_fn=ref.tiles_fn,
                                padded_fn=ref.padded_fn)
        assert not bare.supports_fused
        with pytest.raises(ValueError, match="fused"):
            pp.decode(stream, book, len(syms), backend=bare,
                      strategy="tile", transform=tr)


class TestDecompressBatch:
    def test_matches_per_tensor_decompress(self, rng):
        from repro.core import api
        from repro.data.pipeline import smooth_field

        cs = [api.compress(smooth_field((40, 30 + 11 * i), seed=i), eb=1e-3)
              for i in range(4)]
        outs = api.decompress_batch(cs)
        for c, out in zip(cs, outs):
            ref = np.asarray(api.decompress(c, strategy="tuned"))
            assert np.asarray(out).tobytes() == ref.tobytes()


class TestEncodeParityMatrix:
    """Write-path twin of the decode matrix: every encode backend must emit
    a byte-identical ``EncodedStream`` for the same symbols + codebook, so
    decode never knows which backend wrote the bytes."""

    FIELDS = ("units", "gaps", "counts", "seq_counts")

    def _assert_streams_equal(self, a, b, ctx):
        for f in self.FIELDS:
            assert np.array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(b, f))), (ctx, f)
        assert int(a.total_bits) == int(b.total_bits), ctx
        assert int(a.n_symbols) == int(b.n_symbols), ctx

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("n_syms,max_len,sps", [
        (4000, 12, 32),    # default framing
        (4097, 12, 32),    # crosses a sequence boundary by one symbol
        (129, 8, 4),       # short stream, small sequences
        (777, 16, 32),     # deep codebook
        (50, 4, 32),       # codebook shallower than a unit
        (1, 12, 32),       # single symbol
    ])
    def test_pack_byte_identical(self, rng, backend, n_syms, max_len, sps):
        vocab = min(1024, 1 << max_len)
        book, syms, _ = make_book_and_stream(rng, n_syms=n_syms, vocab=vocab,
                                             max_len=max_len,
                                             subseqs_per_seq=sps)
        freq = np.bincount(syms, minlength=vocab)
        plan = pp.build_encoder_plan(freq, max_len=max_len,
                                     subseqs_per_seq=sps, backend=backend)
        got = pp.encode_with_plan(jnp.asarray(syms), plan, backend=backend)
        want = pp.encode_with_plan(syms, plan, backend="ref")
        self._assert_streams_equal(got, want, (backend, n_syms, max_len, sps))

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_single_used_symbol(self, backend):
        from repro.core.huffman import codebook as cb

        freq = np.zeros(16, np.int64)
        freq[3] = 500
        book = cb.build_codebook(freq, max_len=8)
        syms = np.full(500, 3, np.uint16)
        plan = pp.build_encoder_plan(freq, max_len=8, subseqs_per_seq=32,
                                     backend=backend)
        got = pp.encode_with_plan(jnp.asarray(syms), plan, backend=backend)
        want = pp.encode_with_plan(syms, plan, backend="ref")
        self._assert_streams_equal(got, want, backend)

    @pytest.mark.parametrize("backend", ["ref", "jnp", "pallas"])
    def test_empty_input(self, backend):
        freq = np.zeros(16, np.int64)
        freq[0] = 1   # codebook needs one symbol; the stream holds none
        plan = pp.build_encoder_plan(freq, max_len=8, subseqs_per_seq=32,
                                     backend=backend)
        plan = pp.EncoderPlan(codebook=plan.codebook, enc_code=plan.enc_code,
                              enc_len=plan.enc_len, total_bits=0,
                              subseqs_per_seq=32)
        got = pp.encode_with_plan(jnp.zeros((0,), jnp.uint16), plan,
                                  backend=backend)
        assert int(got.n_symbols) == 0 and int(got.total_bits) == 0
        assert np.all(np.asarray(got.units) == 0)

    def test_stats_counters(self, rng):
        book, syms, _ = make_book_and_stream(rng, n_syms=300)
        freq = np.bincount(syms, minlength=1024)
        be = pp.get_encode_backend("jnp")
        be.reset_stats()
        plan = pp.build_encoder_plan(freq, max_len=12, subseqs_per_seq=32,
                                     backend="jnp")
        pp.encode_with_plan(jnp.asarray(syms), plan, backend="jnp")
        pp.encode_with_plan(jnp.asarray(syms), plan, backend="jnp")
        assert be.stats["encoder_plan_builds"] == 1
        assert be.stats["encode_dispatches"] == 2
        assert be.stats["encode_fallbacks"] == 0

    def test_unknown_encode_backend(self):
        with pytest.raises(ValueError, match="available"):
            pp.get_encode_backend("no_such_encoder")


class TestDeviceCompressParity:
    """End-to-end ``compress(encode_backend=...)``: device x decode matrix."""

    @staticmethod
    def _lattice(rng, n=6000, eb=0.0078125):
        # Values exactly on the 2*eb lattice: the f32 in-graph quantizer and
        # the f64 host prequantizer agree bit-for-bit, so the full payload
        # (not just the decode) must be byte-identical.
        k = rng.integers(-400, 400, size=n).astype(np.int32)
        return (k.astype(np.float32) * np.float32(2 * eb)), eb

    @pytest.mark.parametrize("encode_backend", ["jnp", "pallas"])
    def test_lattice_byte_identical(self, rng, encode_backend):
        from repro.core.sz import compressor as C

        x, eb = self._lattice(rng)
        ref = C.compress(x, eb=eb, mode="abs", encode_backend="ref")
        dev = C.compress(x, eb=eb, mode="abs", encode_backend=encode_backend)
        assert np.array_equal(np.asarray(ref.stream.units),
                              np.asarray(dev.stream.units))
        assert np.array_equal(np.asarray(ref.outlier_pos),
                              np.asarray(dev.outlier_pos))
        assert np.array_equal(np.asarray(ref.outlier_val),
                              np.asarray(dev.outlier_val))

    @pytest.mark.parametrize("encode_backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("decode_backend", ["ref", "pallas"])
    @pytest.mark.parametrize("mode", ["rel", "abs"])
    def test_roundtrip_within_bound(self, rng, encode_backend,
                                    decode_backend, mode):
        from repro.core.sz import compressor as C

        x = rng.normal(size=(61, 47)).astype(np.float32)
        c = C.compress(x, eb=1e-3, mode=mode, encode_backend=encode_backend)
        y = np.asarray(C.decompress(c, backend=decode_backend))
        assert np.max(np.abs(y - x)) <= c.eb_effective

    @pytest.mark.parametrize("n", [31, 4096, 4097, 8191])
    def test_tail_padding_sizes(self, rng, n):
        from repro.core.sz import compressor as C

        x, eb = TestDeviceCompressParity._lattice(rng, n=n)
        ref = C.compress(x, eb=eb, mode="abs", encode_backend="ref")
        dev = C.compress(x, eb=eb, mode="abs", encode_backend="jnp")
        assert np.array_equal(np.asarray(ref.stream.units),
                              np.asarray(dev.stream.units)), n

    def test_forced_outliers_at_radius(self, rng):
        from repro.core.sz import compressor as C

        x = (rng.normal(size=3000) * 100).astype(np.float32)
        x[::11] += 2000.0   # residuals far past the radius
        ref = C.compress(x, eb=0.5, mode="abs", encode_backend="ref")
        dev = C.compress(x, eb=0.5, mode="abs", encode_backend="jnp")
        assert int((np.asarray(ref.outlier_pos) >= 0).sum()) > 0
        assert np.array_equal(np.asarray(ref.outlier_pos),
                              np.asarray(dev.outlier_pos))
        assert np.array_equal(np.asarray(ref.outlier_val),
                              np.asarray(dev.outlier_val))
        y = np.asarray(C.decompress(dev))
        assert np.max(np.abs(y - x)) <= dev.eb_effective

    def test_non_f32_falls_back_counted(self, rng):
        from repro.core.sz import compressor as C

        be = pp.get_encode_backend("jnp")
        be.reset_stats()
        x = rng.normal(size=400).astype(np.float16)
        c = C.compress(x, eb=1e-2, mode="abs", encode_backend="jnp")
        assert be.stats["encode_fallbacks"] == 1
        assert be.stats["encode_dispatches"] == 0   # served by "ref"
        y = np.asarray(C.decompress(c))
        assert y.dtype == np.float16   # fallback preserves the input dtype
        # decompress rounds the reconstruction back to f16, which can add up
        # to half an f16 ulp on top of the error bound
        slack = 0.5 * np.max(np.abs(np.spacing(x.astype(np.float16))))
        err = np.max(np.abs(y.astype(np.float64) - x.astype(np.float64)))
        assert err <= c.eb_effective + slack
