"""Encode -> decode identity for every decoder variant (paper §III/IV)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.huffman import codebook as cb
from repro.core.huffman import decode as hd
from repro.core.huffman import encode as he
from repro.core.huffman import pipeline as hp

from conftest import make_book_and_stream


def _luts(book):
    return jnp.asarray(book.dec_sym), jnp.asarray(book.dec_len)


class TestDecoders:
    @pytest.mark.parametrize("zipf", [1.2, 1.5, 3.0])
    @pytest.mark.parametrize("n", [37, 1000, 6001])
    def test_sequential(self, rng, zipf, n):
        book, syms, stream = make_book_and_stream(rng, n_syms=n, zipf=zipf)
        ds, dl = _luts(book)
        out = hd.decode_sequential(jnp.asarray(stream.units), ds, dl,
                                   n_symbols=n, max_len=book.max_len)
        assert np.array_equal(np.asarray(out), syms)

    @pytest.mark.parametrize("use_tiles", [False, True])
    def test_gap_array(self, rng, use_tiles):
        book, syms, stream = make_book_and_stream(rng, n_syms=5000)
        ds, dl = _luts(book)
        out = hd.decode_gap_array(stream, ds, dl, book.max_len, len(syms),
                                  use_tiles=use_tiles)
        assert np.array_equal(np.asarray(out), syms)

    @pytest.mark.parametrize("early_exit", [False, True])
    def test_selfsync(self, rng, early_exit):
        book, syms, stream = make_book_and_stream(rng, n_syms=5000)
        ds, dl = _luts(book)
        out = hd.decode_selfsync(stream, ds, dl, book.max_len, len(syms),
                                 early_exit=early_exit)
        assert np.array_equal(np.asarray(out), syms)

    def test_selfsync_counts_match_gap(self, rng):
        """Sync discovery must land on the same codeword boundaries the
        encoder recorded in the gap array."""
        book, syms, stream = make_book_and_stream(rng, n_syms=4000)
        ds, dl = _luts(book)
        units = jnp.asarray(stream.units)
        n_sub = stream.gaps.shape[0]
        start, _ = hd.selfsync_intra(units, ds, dl, stream.total_bits, n_sub,
                                     book.max_len, stream.subseqs_per_seq)
        start, _ = hd.selfsync_inter(units, ds, dl, start, stream.total_bits,
                                     book.max_len, stream.subseqs_per_seq)
        expected = (jnp.arange(n_sub) * 128 + stream.gaps.astype(jnp.int32))
        # compare where the stream still has payload
        valid = np.asarray(expected) < int(stream.total_bits)
        assert np.array_equal(np.asarray(start)[valid],
                              np.asarray(expected)[valid])

    def test_chunked_baseline(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=3000)
        ds, dl = _luts(book)
        ch = he.encode_chunked(syms, book.enc_code, book.enc_len,
                               chunk_symbols=512)
        out = hd.decode_chunked(ch["units"], ch["chunk_bits"],
                                ch["chunk_syms"], ds, dl,
                                max_len=book.max_len, chunk_symbols=512)
        assert np.array_equal(np.asarray(out).reshape(-1)[:3000], syms)

    @pytest.mark.parametrize("tile", [1024, 2048, 4096])
    def test_tile_sizes(self, rng, tile):
        book, syms, stream = make_book_and_stream(rng, n_syms=9000)
        ds, dl = _luts(book)
        out = hd.decode_gap_array(stream, ds, dl, book.max_len, len(syms),
                                  tile_syms=tile)
        assert np.array_equal(np.asarray(out), syms)

    def test_tuned(self, rng):
        # mixed compressibility: skewed block + uniform block
        a = rng.choice(1024, size=20000,
                       p=np.r_[0.9, np.full(1023, 0.1 / 1023)])
        b = rng.integers(0, 1024, 20000)
        syms = np.concatenate([a, b]).astype(np.uint16)
        freq = np.bincount(syms, minlength=1024)
        book = cb.build_codebook(freq, max_len=12)
        stream = he.encode(syms, book.enc_code, book.enc_len)
        ds, dl = _luts(book)
        starts = hd.gap_starts(stream)
        bnds = jnp.arange(stream.gaps.shape[0], dtype=jnp.int32) * 128
        _, counts = hd.subseq_scan(jnp.asarray(stream.units), ds, dl, starts,
                                   bnds + 128, stream.total_bits, 12)
        out = hp.execute_tuned(stream, ds, dl, 12, len(syms), starts,
                                  counts)
        assert np.array_equal(np.asarray(out), syms)

    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 2000), st.integers(2, 200), st.integers(0, 2**31))
    def test_property_roundtrip(self, n, vocab, seed):
        r = np.random.default_rng(seed)
        freq = r.integers(0, 50, size=vocab)
        syms = r.integers(0, vocab, size=n).astype(np.uint16)
        freq = np.maximum(freq, np.bincount(syms, minlength=vocab))
        book = cb.build_codebook(freq, max_len=12)
        stream = he.encode(syms, book.enc_code, book.enc_len)
        ds, dl = _luts(book)
        out = hd.decode_gap_array(stream, ds, dl, 12, n)
        assert np.array_equal(np.asarray(out), syms)
        out2 = hd.decode_selfsync(stream, ds, dl, 12, n)
        assert np.array_equal(np.asarray(out2), syms)


class TestEncoderMetadata:
    def test_gap_points_to_codeword_start(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=2000)
        lens = book.enc_len[syms].astype(np.int64)
        starts = np.cumsum(lens) - lens
        gaps = np.asarray(stream.gaps)
        total = int(stream.total_bits)
        for i in range(stream.gaps.shape[0]):
            b = i * 128
            if b >= total:
                continue
            nxt = starts[starts >= b]
            if len(nxt) == 0:
                continue
            if nxt[0] - b > 255:
                continue  # gap byte saturates in the padded tail region
            assert b + int(gaps[i]) == nxt[0]

    def test_counts_sum(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=2500)
        assert int(np.asarray(stream.counts).sum()) == 2500
        assert int(np.asarray(stream.seq_counts).sum()) == 2500

    def test_compression_ratio_sane(self, rng):
        book, syms, stream = make_book_and_stream(rng, n_syms=8000, zipf=1.2)
        bits = int(stream.total_bits)
        assert bits < 16 * 8000  # beats raw uint16
        assert bits >= 8000      # >= 1 bit per symbol
